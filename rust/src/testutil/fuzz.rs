//! Deterministic differential fuzz harness: dense vs paged decode engine.
//!
//! Block reuse, prefix sharing, copy-on-write, and LRU eviction are the
//! kind of bookkeeping where a subtle bug produces *plausible* tokens —
//! wrong ones, silently. The pin: a seeded workload generator (random
//! admission times, prompt lengths, shared-prefix families, divergent
//! suffixes, stop conditions, deliberate rejects) runs the SAME workload
//! through the dense seed engine and the paged engine and asserts
//! token-stream equality — every sequence's generated ids, bit for bit —
//! at 1/2/8 threads, with the paged pool sized tight enough that
//! admission waits, prefix-cache eviction, and copy-on-write all fire.
//! Paged-store invariants (`Engine::check_paged_invariants`) are
//! verified after every scheduler step along the way.
//!
//! Everything derives from one `u64` seed, so a CI failure is
//! reproducible from the single number in the log:
//! `differential_fuzz_case(seed)` (see the `fuzz-smoke` CI job and
//! `tests/props.rs`' pinned seeds).

use super::fixtures;
use crate::config::Method;
use crate::engine::{Engine, FinishReason, GenConfig, GenOutput, GenRequest};
use crate::model::Params;
use crate::quant::QuantizedModel;
use crate::runtime::Runtime;
use crate::tensor::{par, Rng};
use anyhow::{bail, Result};
use std::time::Duration;

/// Workload shape, fully derived from one seed.
#[derive(Clone, Debug)]
pub struct FuzzSpec {
    pub seed: u64,
    pub requests: usize,
    pub slots: usize,
    pub block_tokens: usize,
    pub pool_blocks: usize,
    /// Cap on `prompt + max_new` for valid requests (also keeps valid
    /// requests inside the paged capacity, so rejection behavior cannot
    /// differ between the engines).
    pub max_total: usize,
    pub temperature: f32,
    pub top_k: usize,
}

impl FuzzSpec {
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x00FA_C0DE);
        let block_tokens = 3 + rng.below(6); // 3..=8
        let slots = 2 + rng.below(3); // 2..=4
        let max_total = 16 + rng.below(17); // 16..=32
        let per_seq = (max_total - 1).div_ceil(block_tokens);
        // Room for ~1.5 worst-case sequences plus a little slack: small
        // enough that admission regularly waits on blocks and evicts
        // cached prefixes, large enough that any single request fits.
        let pool_blocks = per_seq + per_seq / 2 + 1 + rng.below(per_seq + 1);
        let temperature = [0.0f32, 0.7, 1.0][rng.below(3)];
        let top_k = [0usize, 8][rng.below(2)];
        Self {
            seed,
            requests: 10 + rng.below(7),
            slots,
            block_tokens,
            pool_blocks,
            max_total,
            temperature,
            top_k,
        }
    }
}

/// Build the workload: `(admission step, request)` pairs in submission
/// order. Roughly 60% of requests extend a shared-prefix family (with a
/// random divergent suffix), and a sprinkle are deliberately invalid so
/// rejection behavior is covered too.
pub fn build_workload(vocab: usize, seq: usize, spec: &FuzzSpec) -> Vec<(usize, GenRequest)> {
    let mut rng = Rng::new(spec.seed ^ 0xB10C);
    let n_fam = 2 + rng.below(3);
    let families: Vec<Vec<i32>> = (0..n_fam)
        .map(|_| {
            let len = 4 + rng.below(spec.max_total / 2);
            (0..len).map(|_| rng.below(vocab) as i32).collect()
        })
        .collect();
    let mut out = Vec::new();
    let mut step = 0usize;
    for id in 0..spec.requests {
        step += rng.below(4); // random admission times
        let kind = rng.below(10);
        let prompt: Vec<i32> = if kind == 0 {
            // Oversize for BOTH engines: prompt alone exceeds T_max.
            let plen = seq + 1 + rng.below(8);
            (0..plen).map(|_| rng.below(vocab) as i32).collect()
        } else if kind <= 6 {
            // Shared-prefix family + divergent suffix (mid-block
            // divergence exercises copy-on-write and radix splits).
            let fam = &families[rng.below(n_fam)];
            let keep = 1 + rng.below(fam.len());
            let mut p: Vec<i32> = fam[..keep].to_vec();
            for _ in 0..rng.below(4) {
                p.push(rng.below(vocab) as i32);
            }
            p
        } else {
            let plen = 2 + rng.below(spec.max_total / 2);
            (0..plen).map(|_| rng.below(vocab) as i32).collect()
        };
        let max_new = if kind == 1 {
            0 // rejected (ZeroMaxNew) by both engines
        } else if prompt.len() >= spec.max_total {
            1 // oversize prompts: any budget, rejected anyway
        } else {
            1 + rng.below(spec.max_total - prompt.len())
        };
        let stop_id = (rng.below(10) < 3).then(|| rng.below(vocab) as i32);
        out.push((
            step,
            GenRequest {
                id,
                prompt,
                max_new,
                stop_id,
                ..Default::default()
            },
        ));
    }
    out
}

/// Whether a workload request runs normally on both engines (as opposed
/// to being one of the deliberately invalid ones rejected at submit).
/// The fault-injection harness (`testutil::faults`) uses this to pick
/// its victims: faults must land on requests that actually decode.
pub fn request_is_valid(r: &GenRequest, spec: &FuzzSpec) -> bool {
    !r.prompt.is_empty()
        && r.max_new >= 1
        && r.prompt.len() + r.max_new <= spec.max_total
        && r.prompt.iter().all(|&t| t >= 0)
}

/// Drive one engine through the workload: submissions happen at their
/// admission step (between decode steps — the continuous-batching
/// ingress), invariants optionally checked after every step. Returns all
/// outputs (rejections included) sorted by request id.
pub fn run_workload(
    rt: &Runtime,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    workload: &[(usize, GenRequest)],
    check_invariants: bool,
) -> Result<Vec<GenOutput>> {
    let cfg = fixtures::pico();
    let mut eng = Engine::new(rt, &cfg, params, qm, gen)?;
    drive(&mut eng, workload, check_invariants)
}

/// Like [`run_workload`], but also returns the canonically-rendered
/// trace-event lines (set `gen.trace = true` and a `virtual_step` to get
/// deterministic, cross-thread-comparable lines).
pub fn run_workload_traced(
    rt: &Runtime,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    workload: &[(usize, GenRequest)],
    check_invariants: bool,
) -> Result<(Vec<GenOutput>, Vec<String>)> {
    let cfg = fixtures::pico();
    let mut eng = Engine::new(rt, &cfg, params, qm, gen)?;
    let outs = drive(&mut eng, workload, check_invariants)?;
    let lines = eng.trace().canonical_lines();
    Ok((outs, lines))
}

fn drive(
    eng: &mut Engine<'_>,
    workload: &[(usize, GenRequest)],
    check_invariants: bool,
) -> Result<Vec<GenOutput>> {
    let mut outs = Vec::new();
    let mut next = 0usize;
    let mut step = 0usize;
    // Generous bound (every workload drains in far fewer steps): an
    // admission-livelock regression must FAIL with the seed in the log,
    // not hang the fuzz-smoke job until the CI timeout.
    let step_bound = 10_000 + workload.iter().map(|(at, _)| *at).max().unwrap_or(0);
    while next < workload.len() || eng.has_work() {
        while next < workload.len() && workload[next].0 <= step {
            if let Some(rejected) = eng.submit(workload[next].1.clone()) {
                outs.push(rejected);
            }
            next += 1;
        }
        outs.extend(eng.step()?);
        if check_invariants {
            eng.check_paged_invariants()?;
        }
        step += 1;
        if step > step_bound {
            bail!(
                "engine failed to drain the workload within {step_bound} steps \
                 (admission livelock?): {} of {} requests finished",
                outs.len(),
                workload.len()
            );
        }
    }
    outs.sort_by_key(|o| o.id);
    Ok(outs)
}

/// Token streams (and finish causes) must match request for request.
/// Rejection reasons are compared by cause: the paged engine legitimately
/// reports its own (block-derived) capacity inside `TooLong`.
pub fn assert_streams_equal(a: &[GenOutput], b: &[GenOutput], ctx: &str) -> Result<()> {
    if a.len() != b.len() {
        bail!("{ctx}: {} vs {} outputs", a.len(), b.len());
    }
    for (x, y) in a.iter().zip(b) {
        if x.id != y.id || x.prompt_len != y.prompt_len {
            bail!("{ctx}: output identity mismatch (ids {} vs {})", x.id, y.id);
        }
        if x.tokens != y.tokens {
            bail!(
                "{ctx}: request {} token streams diverge:\n  a: {:?}\n  b: {:?}",
                x.id,
                x.tokens,
                y.tokens
            );
        }
        let same_finish = match (&x.finish, &y.finish) {
            (FinishReason::Rejected(r1), FinishReason::Rejected(r2)) => {
                r1.cause() == r2.cause()
            }
            (f1, f2) => f1 == f2,
        };
        if !same_finish {
            bail!(
                "{ctx}: request {} finish mismatch: {:?} vs {:?}",
                x.id,
                x.finish,
                y.finish
            );
        }
    }
    Ok(())
}

/// One full differential case from a single seed: build a pico artifact
/// and a workload, run the dense engine (1 thread) as the oracle, and
/// pin the paged engine against it at 1/2/8 threads (plus the dense
/// engine at 8 threads, closing the square). Panics on divergence with
/// the seed in the message; prints the spec so failures reproduce from
/// the log alone.
pub fn differential_fuzz_case(seed: u64) -> Result<()> {
    let spec = FuzzSpec::from_seed(seed);
    println!("differential fuzz seed {seed}: {spec:?}");
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = build_workload(cfg.vocab, cfg.seq, &spec);
    let dense = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: false,
        ..GenConfig::default()
    };
    let paged = GenConfig {
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        ..dense.clone()
    };

    par::set_threads(1);
    let baseline = run_workload(&rt, &params, &qm, dense.clone(), &workload, false);
    par::set_threads(0);
    let baseline = baseline?;
    if baseline.iter().all(|o| o.tokens.is_empty()) {
        // Statistically (near-)impossible, but a fresh CI-derived seed
        // must never fail on workload shape alone — only on divergence.
        println!("note: degenerate workload (seed {seed}): no tokens generated");
    }

    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let got = run_workload(&rt, &params, &qm, paged.clone(), &workload, true);
        par::set_threads(0);
        let got = got?;
        assert_streams_equal(
            &baseline,
            &got,
            &format!("paged vs dense oracle at {threads} threads (fuzz seed {seed})"),
        )?;
    }
    par::set_threads(8);
    let dense8 = run_workload(&rt, &params, &qm, dense, &workload, false);
    par::set_threads(0);
    assert_streams_equal(
        &baseline,
        &dense8?,
        &format!("dense@8 vs dense@1 (fuzz seed {seed})"),
    )?;
    Ok(())
}

/// Differential fuzz for the integer W4A8 decode path (DESIGN.md §17),
/// one seed in, two contracts out:
///
/// 1. **Int-vs-int determinism** (always asserted): the int path is a
///    deterministic function of the tokens fed — dense-int at 1 thread
///    is the oracle, paged-int must match it bit for bit at 1/2/8
///    threads (and dense-int at 8 threads closes the square). Kernel
///    lane and thread count never change int logits, so any divergence
///    here is a paging/scheduling bug, same as the f32 harness.
/// 2. **Int-vs-f32 greedy agreement** (counted, asserted only with
///    `require_exact`): int logits track the f32 prepared path within
///    the derived bound, not bitwise, so greedy argmax can flip on
///    near-tied logits. Every run reports per-request prefix agreement
///    against the f32 oracle; pinned seeds (pre-screened for top-2
///    margin, `tests/props.rs`) demand full-stream equality, fresh CI
///    seeds (`FAQUANT_INT_SEED`) only report the count — they must
///    never fail on margin luck alone.
///
/// The workload is the shared fuzz workload with sampling forced greedy
/// (temperature/top_k randomness would compound a one-ULP probability
/// shift into guaranteed divergence, pinning nothing).
pub fn int_compute_fuzz_case(seed: u64, require_exact: bool) -> Result<()> {
    let mut spec = FuzzSpec::from_seed(seed);
    spec.temperature = 0.0;
    spec.top_k = 0;
    println!("int-compute fuzz seed {seed}: {spec:?}");
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = build_workload(cfg.vocab, cfg.seq, &spec);
    let f32_dense = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: false,
        ..GenConfig::default()
    };
    let int_dense = GenConfig {
        int_compute: true,
        ..f32_dense.clone()
    };
    let int_paged = GenConfig {
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        ..int_dense.clone()
    };

    par::set_threads(1);
    let oracle_f32 = run_workload(&rt, &params, &qm, f32_dense, &workload, false);
    let oracle_int = run_workload(&rt, &params, &qm, int_dense.clone(), &workload, false);
    par::set_threads(0);
    let oracle_f32 = oracle_f32?;
    let oracle_int = oracle_int?;

    // Contract 1: int-vs-int, bit for bit, across stores and threads.
    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let got = run_workload(&rt, &params, &qm, int_paged.clone(), &workload, true);
        par::set_threads(0);
        assert_streams_equal(
            &oracle_int,
            &got?,
            &format!("paged-int vs dense-int oracle at {threads} threads (int seed {seed})"),
        )?;
    }
    par::set_threads(8);
    let int8t = run_workload(&rt, &params, &qm, int_dense, &workload, false);
    par::set_threads(0);
    assert_streams_equal(
        &oracle_int,
        &int8t?,
        &format!("dense-int@8 vs dense-int@1 (int seed {seed})"),
    )?;

    // Contract 2: greedy agreement vs the f32 prepared oracle. Only the
    // common prefix is comparable — after the first flipped token the
    // two decodes condition on different contexts.
    let mut agreed = 0usize;
    let mut total = 0usize;
    let mut flipped = 0usize;
    for (f, i) in oracle_f32.iter().zip(&oracle_int) {
        if f.id != i.id {
            bail!("int seed {seed}: output ids diverge ({} vs {})", f.id, i.id);
        }
        let pre = f
            .tokens
            .iter()
            .zip(&i.tokens)
            .take_while(|(a, b)| a == b)
            .count();
        agreed += pre;
        total += f.tokens.len().max(i.tokens.len());
        if pre < f.tokens.len().max(i.tokens.len()) {
            flipped += 1;
        }
    }
    println!(
        "int seed {seed}: int-vs-f32 greedy agreement {agreed}/{total} tokens \
         ({flipped} of {} requests flipped)",
        oracle_f32.len()
    );
    if require_exact {
        assert_streams_equal(
            &oracle_f32,
            &oracle_int,
            &format!("int vs f32 greedy streams (pinned int seed {seed})"),
        )?;
    }
    Ok(())
}

/// Trace-determinism pin (DESIGN.md §15), one seed in, two contracts out:
///
/// 1. **Observer effect**: enabling tracing must not perturb generation —
///    the traced paged engine's token streams are bitwise identical to
///    the untraced run's.
/// 2. **Reproducibility**: under the virtual clock, the canonically
///    rendered event sequence is identical at 1/2/8 compute threads (all
///    events are emitted from the scheduler thread, stamped with tick
///    time and a global sequence number — worker-thread count must be
///    invisible).
pub fn trace_determinism_case(seed: u64) -> Result<()> {
    let spec = FuzzSpec::from_seed(seed);
    println!("trace determinism seed {seed}: {spec:?}");
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = build_workload(cfg.vocab, cfg.seq, &spec);
    let untraced_cfg = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        virtual_step: Some(Duration::from_millis(1)),
        ..GenConfig::default()
    };
    let traced_cfg = GenConfig {
        trace: true,
        ..untraced_cfg.clone()
    };

    par::set_threads(1);
    let untraced = run_workload(&rt, &params, &qm, untraced_cfg, &workload, false);
    par::set_threads(0);
    let untraced = untraced?;

    let mut reference: Option<Vec<String>> = None;
    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let got = run_workload_traced(&rt, &params, &qm, traced_cfg.clone(), &workload, true);
        par::set_threads(0);
        let (outs, lines) = got?;
        assert_streams_equal(
            &untraced,
            &outs,
            &format!("traced vs untraced at {threads} threads (trace seed {seed})"),
        )?;
        if lines.is_empty() {
            bail!("trace seed {seed}: traced run produced no events");
        }
        match &reference {
            None => reference = Some(lines),
            Some(want) => {
                if *want != lines {
                    let i = want
                        .iter()
                        .zip(&lines)
                        .position(|(a, b)| a != b)
                        .unwrap_or_else(|| want.len().min(lines.len()));
                    bail!(
                        "trace seed {seed}: event sequence diverges at {threads} threads \
                         ({} vs {} events), first at line {i}:\n  want: {:?}\n  got:  {:?}",
                        want.len(),
                        lines.len(),
                        want.get(i),
                        lines.get(i)
                    );
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_and_workload_are_seed_deterministic() {
        let a = FuzzSpec::from_seed(42);
        let b = FuzzSpec::from_seed(42);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        let wa = build_workload(256, 128, &a);
        let wb = build_workload(256, 128, &b);
        assert_eq!(wa.len(), wb.len());
        for ((sa, ra), (sb, rb)) in wa.iter().zip(&wb) {
            assert_eq!(sa, sb);
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.max_new, rb.max_new);
            assert_eq!(ra.stop_id, rb.stop_id);
        }
        assert_ne!(
            format!("{:?}", FuzzSpec::from_seed(43)),
            format!("{a:?}"),
            "different seeds should shape different workloads"
        );
    }

    #[test]
    fn workload_valid_requests_fit_both_engines() {
        for seed in [1u64, 99, 12345] {
            let spec = FuzzSpec::from_seed(seed);
            // Single-request feasibility on the paged engine.
            assert!(spec.pool_blocks * spec.block_tokens + 1 >= spec.max_total);
            for (_, r) in build_workload(256, 128, &spec) {
                if r.prompt.len() + r.max_new <= spec.max_total {
                    assert!(r.prompt.iter().all(|&t| t >= 0 && t < 256));
                } else {
                    // Deliberately invalid: must be invalid for BOTH
                    // engines the same way (oversize beyond T_max, or
                    // zero budget).
                    assert!(r.prompt.len() > 128 || r.max_new == 0);
                }
            }
        }
    }
}
