//! Router-level extension of the deterministic fault harness: seeded
//! worker-crash/stall/restart plans driven through the sharded router's
//! [`WorkerFaultHook`] seam, asserting the failover soundness contract
//! (DESIGN.md §16):
//!
//! - every request's final token stream is **bitwise identical** to the
//!   fault-free single-engine run of the same workload — untargeted and
//!   re-routed requests alike, at 1, 2, and 8 compute threads;
//! - nothing is answered twice or dropped (exactly-once answers, zero
//!   orphaned queue entries);
//! - every surviving worker drains with a clean pool check (zero leaked
//!   KV blocks).
//!
//! Faults are keyed on each worker's **cumulative step-attempt
//! counter**, not on wall time, so a plan replays exactly: the crash at
//! attempt `k` fires the first time the target reaches attempt `>= k`
//! and never again (re-execution after restart runs under later
//! attempt numbers).
//!
//! Worker timing is still free-running — only the *streams* are pinned
//! bitwise, which the engine's placement-invariance contract makes
//! sufficient (a stream depends only on `(prompt, gen seed, id,
//! sampling params)`, never on worker placement or batch composition).

use crate::config::Method;
use crate::engine::{GenConfig, GenOutput};
use crate::quant::QuantizedModel;
use crate::model::Params;
use crate::runtime::Runtime;
use crate::serve::router::{run_router, HookFactory, RouterConfig, RouterReport};
use crate::serve::{route_affinity, Stepper, WorkerFaultHook};
use crate::tensor::{par, Rng};
use crate::testutil::{fixtures, fuzz};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// One planned fault against one worker, armed when the worker's
/// cumulative attempt counter reaches `at`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterFault {
    /// Panic inside the step path (absorbed by the worker's
    /// `catch_unwind`; the supervisor restarts it after backoff).
    Crash { worker: usize, at: u64 },
    /// Cooperative wedge: the worker stops making progress with work
    /// queued, until heartbeat supervision quarantines it.
    Stall { worker: usize, at: u64 },
}

impl RouterFault {
    fn worker(&self) -> usize {
        match *self {
            RouterFault::Crash { worker, .. } | RouterFault::Stall { worker, .. } => worker,
        }
    }
}

/// A seeded schedule of worker faults over one fuzz workload.
#[derive(Clone, Debug)]
pub struct RouterFaultPlan {
    pub seed: u64,
    pub workers: usize,
    pub faults: Vec<RouterFault>,
    /// True when the plan provably fires at least one crash: the
    /// primary target is the prefix-affinity worker of a valid request
    /// (so it receives work) and its crash arms at attempt 1 (so it
    /// fires on the target's very first step). Cases assert
    /// `crashes >= 1` only under this flag — later-attempt faults are
    /// best-effort extra chaos.
    pub guaranteed: bool,
}

impl RouterFaultPlan {
    /// Derive the plan from the case seed alone. The primary crash
    /// targets the worker that prefix-affinity routing will send the
    /// first *valid* complete-block request to — the one worker certain
    /// to hold in-flight work worth failing over.
    pub fn from_seed(
        seed: u64,
        workers: usize,
        workload: &[(usize, crate::engine::GenRequest)],
        spec: &fuzz::FuzzSpec,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x0040_F7A1);
        let mut faults = Vec::new();
        let mut guaranteed = false;
        let primary = workload
            .iter()
            .filter(|(_, r)| fuzz::request_is_valid(r, spec))
            .find_map(|(_, r)| route_affinity(&r.prompt, spec.block_tokens, workers));
        if let Some(target) = primary {
            faults.push(RouterFault::Crash { worker: target, at: 1 });
            guaranteed = true;
            // Best-effort second crash on the same worker, later in its
            // (cumulative) attempt stream: exercises crash-after-restart.
            if rng.below(2) == 0 {
                faults.push(RouterFault::Crash {
                    worker: target,
                    at: 4 + rng.below(6) as u64,
                });
            }
            // Best-effort stall on a different worker when the fleet
            // has one: exercises heartbeat quarantine + re-execution.
            if workers > 1 && rng.below(2) == 0 {
                let other = (target + 1 + rng.below(workers - 1)) % workers;
                faults.push(RouterFault::Stall {
                    worker: other,
                    at: 1 + rng.below(3) as u64,
                });
            }
        }
        Self {
            seed,
            workers,
            faults,
            guaranteed,
        }
    }

    /// The harness router configuration: affinity on (the plan's
    /// targeting depends on it), generous per-worker queue so dispatch
    /// never falls back for capacity reasons, tight supervision knobs
    /// so quarantine/restart land within test budgets, and router
    /// tracing on so cases can assert crash/failover events fired.
    pub fn router_config(&self) -> RouterConfig {
        let plan = self.clone();
        let hook: HookFactory = Arc::new(move |w| plan.hook_for(w));
        RouterConfig {
            workers: self.workers,
            affinity: true,
            max_queue: 0,
            worker_queue: 64,
            stall_rounds: 25,
            restart_backoff: Duration::from_millis(2),
            max_restarts: 6,
            trace: true,
            virtual_step: Some(Duration::from_millis(1)),
            hook: Some(hook),
        }
    }

    fn hook_for(&self, worker: usize) -> Option<Box<dyn WorkerFaultHook>> {
        let mine: Vec<ArmedFault> = self
            .faults
            .iter()
            .filter(|f| f.worker() == worker)
            .map(|&fault| ArmedFault { fault, fired: false })
            .collect();
        if mine.is_empty() {
            return None;
        }
        Some(Box::new(PlanHook {
            seed: self.seed,
            faults: mine,
        }))
    }
}

struct ArmedFault {
    fault: RouterFault,
    fired: bool,
}

/// The [`WorkerFaultHook`] executing one worker's slice of the plan.
struct PlanHook {
    seed: u64,
    faults: Vec<ArmedFault>,
}

impl WorkerFaultHook for PlanHook {
    fn before_step(&mut self, worker: usize, epoch: usize, attempt: u64) -> bool {
        for f in &mut self.faults {
            if f.fired {
                continue;
            }
            match f.fault {
                RouterFault::Crash { at, .. } if attempt >= at => {
                    f.fired = true;
                    panic!(
                        "router fault plan {:#x}: injected crash on worker {worker} \
                         (epoch {epoch}, attempt {attempt})",
                        self.seed
                    );
                }
                RouterFault::Stall { at, .. } if attempt >= at => {
                    f.fired = true;
                    return true;
                }
                _ => {}
            }
        }
        false
    }
}

/// Drive the sharded router through a fuzz workload exactly like
/// `fuzz::run_workload` drives a single engine: submissions at their
/// admission step, then step until drained. Returns outputs sorted by
/// request id plus the run's [`RouterReport`].
pub fn run_sharded_workload(
    rt: &Runtime,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    rcfg: RouterConfig,
    workload: &[(usize, crate::engine::GenRequest)],
) -> Result<(Vec<GenOutput>, RouterReport)> {
    let cfg = fixtures::pico();
    run_router(rt, &cfg, params, qm, gen, rcfg, |router| {
        let mut outs = Vec::new();
        let mut next = 0usize;
        let mut step = 0usize;
        // Router steps block ~1ms when idle with in-flight work, so
        // this bound also caps wall time if something wedges without
        // being caught — the case FAILS with the seed in the log
        // rather than hanging the job.
        let step_bound = 100_000 + workload.iter().map(|(at, _)| *at).max().unwrap_or(0);
        while next < workload.len() || router.has_work() {
            while next < workload.len() && workload[next].0 <= step {
                if let Some(rejected) = router.submit(workload[next].1.clone()) {
                    outs.push(rejected);
                }
                next += 1;
            }
            outs.extend(router.step()?);
            step += 1;
            if step > step_bound {
                bail!(
                    "router failed to drain the workload within {step_bound} steps: \
                     {} of {} requests answered",
                    outs.len(),
                    workload.len()
                );
            }
        }
        outs.sort_by_key(|o| o.id);
        Ok(outs)
    })
}

/// The full failover case for one seed and worker count: fault-free
/// single-engine baseline at 1 thread, then the faulted sharded run at
/// 1/2/8 threads, asserting stream bit-identity against the baseline
/// plus the zero-orphan / zero-leak / no-permanent-down contract.
pub fn router_failover_case(seed: u64, workers: usize) -> Result<()> {
    let spec = fuzz::FuzzSpec::from_seed(seed);
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = fuzz::build_workload(cfg.vocab, cfg.seq, &spec);
    let plan = RouterFaultPlan::from_seed(seed, workers, &workload, &spec);
    println!("router-failover seed {seed} ({workers} workers): {spec:?}\n  plan: {plan:?}");
    if plan.guaranteed {
        println!("  (injected worker panics below are expected — absorbed by catch_unwind)");
    }
    let gen = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        ..GenConfig::default()
    };

    par::set_threads(1);
    let baseline = fuzz::run_workload(&rt, &params, &qm, gen.clone(), &workload, false);
    par::set_threads(0);
    let baseline = baseline?;

    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let res = run_sharded_workload(
            &rt,
            &params,
            &qm,
            gen.clone(),
            plan.router_config(),
            &workload,
        );
        par::set_threads(0);
        let (outs, report) = res?;
        let ctx = format!("sharded vs single engine at {threads} threads (router seed {seed})");
        fuzz::assert_streams_equal(&baseline, &outs, &ctx)?;
        check_router_accounting(seed, threads, workload.len(), &outs, &report)?;
        if plan.guaranteed {
            if report.crashes == 0 {
                bail!(
                    "router seed {seed}: guaranteed crash plan fired no crash at \
                     {threads} threads\n  report: {}",
                    report.summary_line()
                );
            }
            if !report.trace.iter().any(|r| r.ev.kind() == "worker_crash") {
                bail!("router seed {seed}: crash happened but no worker_crash trace event");
            }
            if report.rerouted > 0 && !report.trace.iter().any(|r| r.ev.kind() == "failover") {
                bail!("router seed {seed}: rerouted {} requests without failover trace events",
                    report.rerouted
                );
            }
        }
    }
    Ok(())
}

/// The exactly-once / zero-orphan / zero-leak contract shared by the
/// failover cases and the clean-drain accounting test.
pub fn check_router_accounting(
    seed: u64,
    threads: usize,
    expected_answers: usize,
    outs: &[GenOutput],
    report: &RouterReport,
) -> Result<()> {
    if outs.len() != expected_answers {
        bail!(
            "router seed {seed} at {threads} threads: {} answers for {expected_answers} requests",
            outs.len()
        );
    }
    for pair in outs.windows(2) {
        if let [a, b] = pair {
            if a.id == b.id {
                bail!("router seed {seed}: request {} answered twice", a.id);
            }
        }
    }
    if report.orphaned != 0 {
        bail!(
            "router seed {seed} at {threads} threads: {} orphaned queue entries after drain",
            report.orphaned
        );
    }
    if !report.leaks.is_empty() {
        bail!(
            "router seed {seed} at {threads} threads: leaked KV blocks after drain: {:?}",
            report.leaks
        );
    }
    if !report.down.is_empty() {
        bail!(
            "router seed {seed} at {threads} threads: workers went permanently down: {:?}",
            report.down
        );
    }
    let per_worker_done: usize = report.per_worker.iter().map(|w| w.completed).sum();
    if per_worker_done != report.completed {
        bail!(
            "router seed {seed}: per-worker answers ({per_worker_done}) disagree with \
             fleet total ({})",
            report.completed
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_seed_deterministic() {
        let spec = fuzz::FuzzSpec::from_seed(11);
        let w = fuzz::build_workload(256, 128, &spec);
        let a = RouterFaultPlan::from_seed(11, 4, &w, &spec);
        let b = RouterFaultPlan::from_seed(11, 4, &w, &spec);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn guaranteed_plans_arm_their_primary_crash_at_attempt_one() {
        for seed in [1u64, 2, 3, 0x40F7_0001, 0x40F7_0002, 0x40F7_0003] {
            let spec = fuzz::FuzzSpec::from_seed(seed);
            let w = fuzz::build_workload(256, 128, &spec);
            for workers in [1usize, 2, 4, 8] {
                let plan = RouterFaultPlan::from_seed(seed, workers, &w, &spec);
                if plan.guaranteed {
                    assert!(
                        plan.faults
                            .iter()
                            .any(|f| matches!(f, RouterFault::Crash { at: 1, .. })),
                        "seed {seed}: guaranteed plan lacks an attempt-1 crash: {plan:?}"
                    );
                }
                for f in &plan.faults {
                    assert!(f.worker() < workers, "seed {seed}: fault off-fleet: {f:?}");
                }
            }
        }
    }

    #[test]
    fn stall_never_targets_the_crash_worker() {
        for seed in 0..32u64 {
            let spec = fuzz::FuzzSpec::from_seed(seed);
            let w = fuzz::build_workload(256, 128, &spec);
            let plan = RouterFaultPlan::from_seed(seed, 4, &w, &spec);
            let crash_workers: Vec<usize> = plan
                .faults
                .iter()
                .filter(|f| matches!(f, RouterFault::Crash { .. }))
                .map(|f| f.worker())
                .collect();
            for f in &plan.faults {
                if matches!(f, RouterFault::Stall { .. }) {
                    assert!(
                        !crash_workers.contains(&f.worker()),
                        "seed {seed}: stall and crash share worker: {plan:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn hook_fires_each_fault_exactly_once() {
        let plan = RouterFaultPlan {
            seed: 0xD00D,
            workers: 2,
            faults: vec![RouterFault::Stall { worker: 1, at: 3 }],
            guaranteed: false,
        };
        let mut hook = plan.hook_for(1).expect("worker 1 has a fault");
        assert!(!hook.before_step(1, 0, 1));
        assert!(!hook.before_step(1, 0, 2));
        assert!(hook.before_step(1, 0, 3), "stall must fire at its attempt");
        assert!(
            !hook.before_step(1, 0, 4),
            "a fired fault must never re-fire"
        );
        assert!(plan.hook_for(0).is_none(), "clean workers carry no hook");
    }
}
