//! faquant — CLI entrypoint for the Future-Aware Quantization framework.
//!
//! Subcommands:
//!   train      — train (or reuse) a checkpoint for a model preset
//!   quantize   — run the PTQ pipeline (calibrate + search + pack)
//!   eval       — quantize then evaluate the full Table-1 metric row
//!   table1/2/3 — regenerate the paper's tables
//!   ablation   — gamma/window hyperparameter sweeps
//!   serve      — batched serving demo on the quantized artifact
//!   generate   — KV-cached continuous-batching generation demo
//!   inspect    — artifact/manifest inventory
//!
//! Every subcommand accepts `--artifacts DIR` (default: artifacts) and
//! `--runs DIR` (default: runs). Run `faquant help` for flag details.

use anyhow::Result;
use faquant::cli::Args;
use faquant::config::{Method, RunConfig};
use faquant::coordinator::Pipeline;
use faquant::eval::report;
use faquant::runtime::Runtime;
use std::path::Path;

const HELP: &str = "\
faquant — Future-Aware Quantization (FAQ) reproduction

USAGE: faquant <subcommand> [flags]

SUBCOMMANDS
  train     --model M [--steps N]            train/reuse a checkpoint
  quantize  --model M [--method fp|rtn|awq|faq] [--bits B] [--gamma G]
            [--window J] [--full-search] [--calib-seqs N]
  eval      (same flags as quantize)         quantize + full metric row
  table1    [--models a,b,c]                 paper Table 1 grid
  table2    [--models a,b]                   paper Table 2 (3 vs 4 bit)
  table3    [--model M] [--ns 16,32,64,128]  paper Table 3 (calib bias)
  ablation  --sweep gamma|window [--model M] hyperparameter sweeps
  serve     --model M [--requests N]         quantized serving demo
  serve bench  [--clients N] [--requests-per-client N] [--prompt-len P]
            [--max-new K] [--shared-prefix L] [--workers N]
            [--affinity on|off] [--gen-seed S] [--json FILE]
            closed-loop load generator over the sharded router: each
            client keeps one request in flight; reports TTFT/per-token
            p50/p95/p99 and writes a benchkit perf JSON (default
            BENCH_perf.json)
  generate  --model M [--prompts N] [--prompt-len P] [--max-new K]
            [--temperature T] [--top-k K] [--gen-seed S] [--stop-id ID]
            [--block-tokens B] [--pool-blocks N] [--dense]
            [--deadline-ms MS] [--max-queue N]
            [--shared-prefix L] [--trace FILE]
            [--workers N] [--affinity on|off] [--int-compute]
            KV-cached generation (greedy when T <= 0; ID < 0 disables).
            Paged KV cache + radix prefix sharing by default; --dense
            pins the seed [L, slots, T, d] slabs (same tokens either way).
            --int-compute decodes on the integer W4A8 path (int8
            activations x stored int4 codes, DESIGN.md §17): logits are
            close-but-not-bitwise vs the f32 panels; needs bits <= 4.
            --deadline-ms caps each request's wall-clock budget (0 = no
            deadline); --max-queue bounds admission (0 = unbounded).
            --shared-prefix gives every prompt the same first L tokens
            (exercises the prefix cache); --trace records engine events
            and writes a Chrome trace-event JSON (load in Perfetto).
            --workers > 1 shards the run across crash-isolated engine
            workers (prefix-affinity routing unless --affinity off);
            the token streams are bit-identical to --workers 1
  inspect                                    list artifacts + configs

COMMON FLAGS
  --artifacts DIR   artifact directory (default artifacts)
  --runs DIR        run/checkpoint directory (default runs)
  --steps N         training steps (default 200)
  --eval-seqs N     eval sequences per corpus (default 32)
  --task-items N    items per zero-shot suite (default 64)
";

fn run_cfg(args: &Args, model: &str) -> Result<RunConfig> {
    let mut cfg = RunConfig::new(model)?;
    if let Some(f) = args.get("config") {
        cfg.apply_file(Path::new(&f))?;
    }
    cfg.artifacts_dir = args.get_or("artifacts", &cfg.artifacts_dir);
    cfg.runs_dir = args.get_or("runs", &cfg.runs_dir);
    cfg.train_steps = args.get_usize("steps", cfg.train_steps)?;
    cfg.eval_seqs = args.get_usize("eval-seqs", cfg.eval_seqs)?;
    cfg.task_items = args.get_usize("task-items", cfg.task_items)?;
    cfg.calib_seqs = args.get_usize("calib-seqs", cfg.calib_seqs)?;
    cfg.calib_seed = args.get_u64("calib-seed", cfg.calib_seed)?;
    cfg.quant.method = Method::parse(&args.get_or("method", "faq"))?;
    cfg.quant.bits = args.get_usize("bits", cfg.quant.bits as usize)? as u32;
    cfg.quant.gamma = args.get_f32("gamma", cfg.quant.gamma)?;
    cfg.quant.window = args.get_usize("window", cfg.quant.window)?;
    cfg.quant.full_search = args.has("full-search");
    cfg.quant.layerwise_preview = args.has("layerwise-preview");
    cfg.quant.validate()?;
    Ok(cfg)
}

fn models_flag(args: &Args, default: &str) -> Vec<String> {
    args.get_or("models", default)
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "" | "help" | "--help" => {
            print!("{HELP}");
            return Ok(());
        }
        _ => {}
    }

    let cfg = run_cfg(&args, &args.get_or("model", "nano"))?;
    let rt = Runtime::for_run(&cfg)?;

    match args.subcommand.as_str() {
        "inspect" => {
            println!("platform: {}", rt.platform());
            println!(
                "group={} loss_rows={}",
                rt.manifest.group, rt.manifest.loss_rows
            );
            let mut names: Vec<_> = rt.manifest.configs.keys().collect();
            names.sort();
            for name in names {
                let c = &rt.manifest.configs[name];
                println!(
                    "config {name}: L={} d={} h={} ff={} V={} ({} params)",
                    c.n_layer,
                    c.d_model,
                    c.n_head,
                    c.d_ff,
                    c.vocab,
                    c.param_count()
                );
            }
            println!("{} artifacts", rt.manifest.artifacts.len());
        }
        "train" => {
            let pipe = Pipeline::new(&rt, cfg.clone());
            let (params, secs) = pipe.checkpoint()?;
            println!(
                "checkpoint ready: {} params in {secs:.1}s -> {}",
                params.param_count(),
                faquant::train::checkpoint_path(&cfg.runs_dir, &cfg.model, cfg.train_steps)
                    .display()
            );
        }
        "quantize" => {
            let pipe = Pipeline::new(&rt, cfg.clone());
            let (params, _) = pipe.checkpoint()?;
            let (calib, _) = pipe.calibrate(&params)?;
            let (qm, secs) = pipe.quantize(&params, Some(&calib))?;
            let (packed, fp) = qm.compression();
            println!(
                "{} b{}: mean recon loss {:.5e}, {packed} B packed vs {fp} B fp32 \
                 ({:.2}x), search {secs:.1}s",
                cfg.quant.method.name(),
                cfg.quant.bits,
                qm.mean_loss(),
                fp as f32 / packed as f32
            );
            for l in qm.linears.iter().take(8) {
                println!(
                    "  blk{}.{:<5} alpha={:.2} loss={:.4e} window={} gamma={:.2}",
                    l.block, l.role, l.alpha, l.loss, l.window_used, l.gamma_used
                );
            }
        }
        "eval" => {
            let pipe = Pipeline::new(&rt, cfg.clone());
            let out = pipe.run()?;
            let row = out.eval.expect("pipeline evaluates");
            println!(
                "{} {} b{}: wikitext2 {:.4}  c4 {:.4}",
                cfg.model.name,
                cfg.quant.method.name(),
                cfg.quant.bits,
                row.ppl_wiki,
                row.ppl_c4
            );
            for (name, acc) in &row.accs {
                println!("  {name:<14} {acc:.4}");
            }
            println!(
                "timings: train {:.1}s capture {:.1}s search {:.1}s eval {:.1}s",
                out.timings.train_secs,
                out.timings.capture_secs,
                out.timings.search_secs,
                out.timings.eval_secs
            );
        }
        "table1" => {
            let models = models_flag(&args, "pico,nano,tiny");
            let refs: Vec<&str> = models.iter().map(String::as_str).collect();
            let t = report::table1(&rt, &refs, &cfg)?;
            println!("{}", t.markdown());
        }
        "table2" => {
            let models = models_flag(&args, "pico,nano");
            let refs: Vec<&str> = models.iter().map(String::as_str).collect();
            let t = report::table2(&rt, &refs, &cfg)?;
            println!("{}", t.markdown());
        }
        "table3" => {
            let ns: Vec<usize> = args
                .get_or("ns", "16,32,64,128")
                .split(',')
                .map(|s| s.parse().unwrap_or(16))
                .collect();
            let t = report::table3(&rt, &args.get_or("model", "nano"), &cfg, &ns)?;
            println!("{}", t.markdown());
        }
        "ablation" => {
            let model = args.get_or("model", "nano");
            match args.get_or("sweep", "gamma").as_str() {
                "gamma" => {
                    let t = report::ablation_gamma(
                        &rt,
                        &model,
                        &cfg,
                        &[0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95],
                    )?;
                    println!("{}", t.markdown());
                }
                "window" => {
                    let t = report::ablation_window(&rt, &model, &cfg, &[1, 2, 3, 4])?;
                    println!("{}", t.markdown());
                }
                other => anyhow::bail!("unknown sweep '{other}' (gamma|window)"),
            }
        }
        "serve" => match args.mode() {
            Some("bench") => serve_bench(&rt, &cfg, &args)?,
            Some(other) => {
                anyhow::bail!("unknown serve mode '{other}' (expected 'serve bench')");
            }
            None => {
                let n_requests = args.get_usize("requests", 64)?;
                serve_demo(&rt, &cfg, n_requests)?;
            }
        },
        "generate" => {
            generate_demo(&rt, &cfg, &args)?;
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}' — run `faquant help`");
        }
    }
    args.finish()?;
    Ok(())
}

/// Generation demo: quantize, then run KV-cached continuous-batching
/// decode over a handful of corpus prompts and print the text + the
/// prefill/decode throughput split.
fn generate_demo(rt: &Runtime, cfg: &RunConfig, args: &faquant::cli::Args) -> Result<()> {
    use faquant::engine::{Engine, FinishReason, GenConfig, GenRequest};

    let n_prompts = args.get_usize("prompts", 4)?;
    let prompt_len = args.get_usize("prompt-len", cfg.model.seq / 4)?;
    let max_new = args.get_usize("max-new", cfg.model.seq / 4)?;
    let temperature = args.get_f32("temperature", 0.8)?;
    let top_k = args.get_usize("top-k", 0)?;
    let gen_seed = args.get_u64("gen-seed", 7)?;
    let stop_id = args.get_i64("stop-id", -1)?;
    let stop_id = (stop_id >= 0).then_some(stop_id as i32);
    let block_tokens = args.get_usize("block-tokens", 0)?;
    let pool_blocks = args.get_usize("pool-blocks", 0)?;
    let dense = args.has("dense");
    let deadline = args.get_ms_opt("deadline-ms")?;
    let max_queue = args.get_usize("max-queue", 0)?;
    let shared_prefix = args.get_usize("shared-prefix", 0)?;
    let trace_path = args.get("trace");
    let workers = args.get_usize("workers", 1)?;
    let affinity = parse_affinity(&args.get_or("affinity", "on"))?;
    let int_compute = args.has("int-compute");

    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    let (qm, _) = pipe.quantize(&params, Some(&calib))?;

    let tok = faquant::eval::canonical_tokenizer(&cfg.model);
    let ids = faquant::eval::calib_ids(&cfg.model, &tok, n_prompts + 4, 99);
    if ids.len() <= prompt_len {
        anyhow::bail!("corpus too small for --prompt-len {prompt_len}");
    }
    let mut prompts: Vec<Vec<i32>> = (0..n_prompts)
        .map(|i| {
            let start = (i * prompt_len) % (ids.len() - prompt_len);
            ids[start..start + prompt_len].to_vec()
        })
        .collect();
    // --shared-prefix: give every prompt an identical head so the radix
    // prefix cache gets real hits (useful when tracing cache behaviour).
    let shared = shared_prefix.min(prompt_len);
    if shared > 0 {
        for p in &mut prompts {
            p[..shared].copy_from_slice(&ids[..shared]);
        }
    }

    let gen = GenConfig {
        temperature,
        top_k,
        seed: gen_seed,
        slots: 0,
        paged: !dense,
        block_tokens,
        pool_blocks,
        max_queue,
        trace: trace_path.is_some(),
        int_compute,
        ..GenConfig::default()
    };
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(id, p)| GenRequest {
            id,
            prompt: p.clone(),
            max_new,
            stop_id,
            deadline,
            ..Default::default()
        })
        .collect();
    // `--workers > 1`: the same workload through the sharded router
    // (crash-isolated engine workers, prefix-affinity routing). The
    // engine bit-identity contract + `(seed, id)`-keyed samplers make
    // the token streams identical to the single-engine path — only the
    // placement and the summary lines differ.
    let (outs, rep, trace_records, trace_dropped, router_summary) = if workers > 1 {
        use faquant::serve::{router::run_router, RouterConfig, Stepper};
        // Admission bounds and tracing move up to the router; worker
        // engines must accept every failover re-dispatch.
        let gen = GenConfig {
            max_queue: 0,
            trace: false,
            ..gen
        };
        let rcfg = RouterConfig {
            workers,
            affinity,
            max_queue,
            trace: trace_path.is_some(),
            ..RouterConfig::default()
        };
        let (mut outs, report) =
            run_router(rt, &cfg.model, &params, &qm, gen, rcfg, |router| {
                let mut outs = Vec::new();
                for req in reqs {
                    if let Some(out) = router.submit(req) {
                        outs.push(out);
                    }
                }
                while router.has_work() {
                    outs.extend(router.step()?);
                }
                Ok(outs)
            })?;
        outs.sort_by_key(|o| o.id);
        let records = report.trace.clone();
        let dropped = report.trace_dropped;
        (
            outs,
            report.engine.clone(),
            records,
            dropped,
            Some(report.summary_line()),
        )
    } else {
        let mut engine = Engine::new(rt, &cfg.model, &params, &qm, gen)?;
        let (outs, rep) = engine.generate(reqs)?;
        let records = engine.trace().snapshot();
        let dropped = engine.trace().dropped();
        (outs, rep, records, dropped, None)
    };

    for out in &outs {
        match &out.finish {
            FinishReason::Rejected(reason) => {
                println!("[{}] rejected: {reason}", out.id);
            }
            finish => {
                let tag = match finish {
                    FinishReason::MaxTokens => "max-tokens",
                    FinishReason::Stop => "stop-id",
                    FinishReason::DeadlineExceeded => "deadline",
                    FinishReason::Cancelled => "cancelled",
                    FinishReason::Rejected(_) => unreachable!(),
                };
                println!(
                    "[{}] {} ++ {}   ({} tokens, {tag})",
                    out.id,
                    tok.decode(&prompts[out.id]),
                    tok.decode(&out.tokens),
                    out.tokens.len(),
                );
            }
        }
    }
    println!(
        "generated {} seqs ({} rejected) in {} steps: prefill {} tok @ {:.0} tok/s, \
         decode {} tok @ {:.0} tok/s, slot occupancy {:.0}%",
        rep.sequences,
        rep.rejected,
        rep.steps,
        rep.prefill_tokens,
        rep.prefill_tps(),
        rep.decode_tokens,
        rep.decode_tps(),
        rep.mean_slot_occupancy * 100.0
    );
    if rep.cancelled + rep.deadline_exceeded + rep.quarantined > 0 {
        println!(
            "lifecycle: {} cancelled, {} deadline-expired, {} quarantined \
             ({} step faults, {} retried)",
            rep.cancelled, rep.deadline_exceeded, rep.quarantined, rep.step_faults, rep.step_retried
        );
    }
    if rep.pool_blocks > 0 {
        println!(
            "paged KV: {} tok/block, peak {} of {} blocks in use, \
             prefix-cache hits {} tok, {} block refs evicted",
            rep.block_tokens,
            rep.peak_blocks_in_use,
            rep.pool_blocks,
            rep.prefix_hit_tokens,
            rep.evicted_blocks
        );
    }
    println!("{}", rep.latency.summary_line());
    if let Some(line) = router_summary {
        println!("{line}");
    }
    if let Some(path) = trace_path {
        std::fs::write(&path, faquant::obs::chrome_trace_json(&trace_records))?;
        println!(
            "trace: {} events ({} dropped) -> {path}",
            trace_records.len(),
            trace_dropped
        );
    }
    Ok(())
}

/// Parse an `--affinity on|off` flag value.
fn parse_affinity(v: &str) -> Result<bool> {
    match v {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("--affinity must be 'on' or 'off', got '{other}'"),
    }
}

/// `serve bench`: closed-loop load generator over the sharded router.
///
/// `--clients` threads each keep exactly one request in flight
/// (send, block on the oneshot answer, repeat `--requests-per-client`
/// times) while the main thread drives `serve_generate_sharded`
/// across `--workers` crash-isolated engines. TTFT / per-token
/// percentiles come from the fleet-merged deterministic engine
/// histograms in the router report; queue percentiles from the serve
/// loop. The run is summarized on stdout and written as a benchkit
/// `PerfReport` JSON (default `BENCH_perf.json` — the same schema the
/// perf bench emits, with the non-serving fields zeroed).
fn serve_bench(rt: &Runtime, cfg: &RunConfig, args: &Args) -> Result<()> {
    use faquant::benchkit::PerfReport;
    use faquant::engine::GenConfig;
    use faquant::serve::{GenServeRequest, GenServeResponse, RouterConfig};
    use std::sync::mpsc;
    use std::time::Duration;

    let clients = args.get_usize("clients", 4)?.max(1);
    let per_client = args.get_usize("requests-per-client", 8)?.max(1);
    let prompt_len = args.get_usize("prompt-len", (cfg.model.seq / 8).max(4))?;
    let max_new = args.get_usize("max-new", (cfg.model.seq / 8).max(4))?;
    let shared_prefix = args.get_usize("shared-prefix", 0)?;
    let workers = args.get_usize("workers", 2)?;
    let affinity = parse_affinity(&args.get_or("affinity", "on"))?;
    let gen_seed = args.get_u64("gen-seed", 7)?;
    let json_path = args.get_or("json", "BENCH_perf.json");

    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    let (qm, _) = pipe.quantize(&params, Some(&calib))?;

    let tok = faquant::eval::canonical_tokenizer(&cfg.model);
    let total = clients * per_client;
    let ids = faquant::eval::calib_ids(&cfg.model, &tok, total + 4, 99);
    if prompt_len == 0 || ids.len() <= prompt_len {
        anyhow::bail!("corpus too small for --prompt-len {prompt_len}");
    }
    // Same prompt mix shape as `generate`: rotating corpus windows with
    // an optional shared head (`--shared-prefix`, exercises both the
    // radix prefix cache and the router's prefix-affinity hash).
    let shared = shared_prefix.min(prompt_len);
    let prompts: Vec<Vec<i32>> = (0..total)
        .map(|i| {
            let start = (i * prompt_len) % (ids.len() - prompt_len);
            let mut p = ids[start..start + prompt_len].to_vec();
            if shared > 0 {
                p[..shared].copy_from_slice(&ids[..shared]);
            }
            p
        })
        .collect();

    let gen = GenConfig {
        seed: gen_seed,
        ..GenConfig::default()
    };
    let rcfg = RouterConfig {
        workers,
        affinity,
        ..RouterConfig::default()
    };
    let (gtx, grx) = mpsc::channel::<GenServeRequest>();
    let (report, served, rejected) = std::thread::scope(|scope| -> Result<_> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let tx = gtx.clone();
                let prompts = &prompts;
                scope.spawn(move || {
                    let (mut done, mut rej) = (0usize, 0usize);
                    for k in 0..per_client {
                        let (rtx, rrx) = faquant::serve::oneshot_channel();
                        let req = GenServeRequest {
                            prompt: prompts[c * per_client + k].clone(),
                            max_new,
                            stop_id: None,
                            deadline: None,
                            cancel: None,
                            respond: rtx,
                        };
                        if tx.send(req).is_err() {
                            break;
                        }
                        match rrx.recv() {
                            Ok(GenServeResponse::Done { .. }) => done += 1,
                            Ok(GenServeResponse::Rejected(_)) => rej += 1,
                            Err(_) => break,
                        }
                    }
                    (done, rej)
                })
            })
            .collect();
        drop(gtx);
        let report = faquant::serve::serve_generate_sharded(
            rt,
            &cfg.model,
            &params,
            &qm,
            gen,
            rcfg,
            grx,
            Duration::from_millis(2),
            None,
        )?;
        let (mut served, mut rejected) = (0usize, 0usize);
        for h in handles {
            if let Ok((d, r)) = h.join() {
                served += d;
                rejected += r;
            }
        }
        Ok((report, served, rejected))
    })?;

    let lat = report.router.latency;
    println!(
        "bench: {clients} clients x {per_client} reqs -> {} answered \
         ({served} completed, {rejected} rejected), queue p50/p95/p99 \
         {:.1}/{:.1}/{:.1} ms",
        report.requests, report.p50_ms, report.p95_ms, report.p99_ms
    );
    println!("{}", lat.summary_line());
    println!("{}", report.router.summary_line());

    let us = |v: u64| v as f32 / 1e6;
    let decode_tokens: usize = report.router.engine.decode_tokens;
    let decode_secs = report.router.engine.decode_secs;
    let perf = PerfReport {
        preset: cfg.model.name.clone(),
        threads: faquant::tensor::par::threads(),
        cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        stages: vec![
            PerfReport::per_token_stage(
                "router_decode_tokens_per_sec",
                decode_tokens,
                decode_secs,
            ),
            PerfReport::per_token_stage(
                "router_prefill_tokens_per_sec",
                report.router.engine.prefill_tokens,
                report.router.engine.prefill_secs,
            ),
        ],
        quantize_secs_1t: 0.0,
        quantize_secs_nt: 0.0,
        speedup: 0.0,
        coordinator_overhead: 0.0,
        prefill_tps: report.router.engine.prefill_tps(),
        decode_tps: report.router.engine.decode_tps(),
        prepare_secs: 0.0,
        decode_prepared_tps: 0.0,
        prefix_hit_prefill_savings: 0.0,
        paged_peak_kv_bytes: 0.0,
        dense_kv_slab_bytes: 0.0,
        ttft_p50: us(lat.ttft_p50_us),
        ttft_p95: us(lat.ttft_p95_us),
        ttft_p99: us(lat.ttft_p99_us),
        per_token_p50: us(lat.per_token_p50_us),
        per_token_p95: us(lat.per_token_p95_us),
        per_token_p99: us(lat.per_token_p99_us),
        queue_wait_p95: us(lat.queue_wait_p95_us),
        router_workers: workers,
        router_ttft_p50: us(lat.ttft_p50_us),
        router_ttft_p95: us(lat.ttft_p95_us),
        router_ttft_p99: us(lat.ttft_p99_us),
        router_per_token_p50: us(lat.per_token_p50_us),
        router_per_token_p95: us(lat.per_token_p95_us),
        router_per_token_p99: us(lat.per_token_p99_us),
        decode_int_tps: 0.0,
        int_kernel: String::new(),
        weight_bytes_f32: 0.0,
        weight_bytes_int: 0.0,
    };
    std::fs::write(&json_path, perf.to_json())?;
    println!("wrote {json_path}");
    Ok(())
}

/// Serving demo: quantize, then fire `n` requests through the batcher.
fn serve_demo(rt: &Runtime, cfg: &RunConfig, n_requests: usize) -> Result<()> {
    use faquant::corpus::Batcher;
    use faquant::eval::calib_ids;
    use std::sync::mpsc;
    use std::time::Duration;

    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    let (qm, _) = pipe.quantize(&params, Some(&calib))?;

    let tok = faquant::eval::canonical_tokenizer(&cfg.model);
    let ids = calib_ids(&cfg.model, &tok, n_requests + cfg.model.batch, 777);
    let batcher = Batcher::new(1, cfg.model.seq);
    let seqs = batcher.eval_batches(&ids)?;

    let (tx, rx) = mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..n_requests {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        let tokens = seqs[i % seqs.len()].data().to_vec();
        tx.send(faquant::serve::Request {
            tokens,
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    drop(tx);
    let rep = faquant::serve::serve_requests(
        rt,
        &cfg.model,
        &params,
        &qm,
        rx,
        Duration::from_millis(5),
        None,
    )?;
    let mut got = 0;
    for r in responders {
        if matches!(r.recv(), Ok(resp) if resp.completion().is_some()) {
            got += 1;
        }
    }
    println!(
        "served {}/{} requests in {} batches (fill {:.0}%), p50 {:.1} ms, p95 {:.1} ms, {:.1} req/s",
        got, rep.requests, rep.batches, rep.mean_batch_fill * 100.0, rep.p50_ms, rep.p95_ms,
        rep.throughput_rps
    );
    Ok(())
}
