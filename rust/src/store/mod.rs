//! `.fqt` binary tensor store (S2): named-tensor checkpoints.
//!
//! Little-endian layout (v2):
//! ```text
//! magic   b"FQT2"
//! u32     n_entries
//! entry*: u16 name_len | name utf8 | u8 dtype (0=f32, 1=i32)
//!         u8 ndim | u64 dims[ndim] | raw LE payload | u64 fnv1a(payload)
//! ```
//! The per-tensor FNV-1a checksum catches silent payload corruption
//! (bit rot, torn writes) at load time, naming the damaged tensor.
//! Legacy `b"FQT1"` files — same layout minus the checksum word — still
//! load; `save` always writes v2.
//!
//! Used for model checkpoints (rust writes, rust reads), quantized model
//! bundles, and calibration stat dumps. Python never reads these — the
//! rust coordinator uploads tensors to PJRT directly.

use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

const MAGIC_V1: &[u8; 4] = b"FQT1";
const MAGIC: &[u8; 4] = b"FQT2";

/// Streaming FNV-1a (64-bit); same constants as the runtime's config
/// fingerprint hasher.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
}

/// An ordered collection of named tensors.
#[derive(Default, Clone, Debug)]
pub struct TensorStore {
    f32s: BTreeMap<String, Tensor>,
    i32s: BTreeMap<String, TensorI32>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.f32s.insert(name.to_string(), t);
    }

    pub fn insert_i32(&mut self, name: &str, t: TensorI32) {
        self.i32s.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.f32s
            .get(name)
            .with_context(|| format!("tensor '{name}' not in store"))
    }

    pub fn get_i32(&self, name: &str) -> Result<&TensorI32> {
        self.i32s
            .get(name)
            .with_context(|| format!("i32 tensor '{name}' not in store"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.f32s.contains_key(name) || self.i32s.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.f32s
            .keys()
            .chain(self.i32s.keys())
            .map(|s| s.as_str())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.f32s.len() + self.i32s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.len() as u32).to_le_bytes())?;
        for (name, t) in &self.f32s {
            write_header(&mut w, name, 0, t.shape())?;
            let mut fnv = Fnv::new();
            for v in t.data() {
                let le = v.to_le_bytes();
                fnv.update(&le);
                w.write_all(&le)?;
            }
            w.write_all(&fnv.0.to_le_bytes())?;
        }
        for (name, t) in &self.i32s {
            write_header(&mut w, name, 1, t.shape())?;
            let mut fnv = Fnv::new();
            for v in t.data() {
                let le = v.to_le_bytes();
                fnv.update(&le);
                w.write_all(&le)?;
            }
            w.write_all(&fnv.0.to_le_bytes())?;
        }
        Ok(())
    }

    /// Load a store, defensively: header-declared sizes are bounded
    /// against the remaining file length BEFORE any allocation (a
    /// truncated or corrupt file fails with a clear error, never an OOM
    /// or a bare `read_exact` EOF), `numel` uses checked multiplication,
    /// and duplicate tensor names are rejected.
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path).with_context(|| format!("open {}", path.display()))?;
        let mut c = Cursor {
            buf: &buf,
            off: 0,
            path,
        };
        let magic = c.bytes(4, "magic")?;
        let checked = match magic {
            m if m == MAGIC => true,
            m if m == MAGIC_V1 => false,
            m => bail!("{}: bad magic {m:?}", path.display()),
        };
        let n = c.u32("entry count")? as usize;
        let mut store = Self::new();
        for e in 0..n {
            let entry = format!("entry {e}/{n}");
            let name_len = c.u16(&entry)? as usize;
            let name = String::from_utf8(c.bytes(name_len, &entry)?.to_vec())
                .with_context(|| format!("{entry}: tensor name not utf8"))?;
            if store.contains(&name) {
                bail!("{}: duplicate tensor name '{name}'", path.display());
            }
            let dtype = c.u8(&name)?;
            let ndim = c.u8(&name)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let dim = usize::try_from(c.u64(&name)?)
                    .map_err(|_| anyhow::anyhow!("tensor '{name}': dimension exceeds usize"))?;
                shape.push(dim);
            }
            let numel = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .with_context(|| {
                    format!("tensor '{name}': shape {shape:?} element count overflows")
                })?;
            let payload_bytes = numel
                .checked_mul(4)
                .with_context(|| format!("tensor '{name}': payload size overflows"))?;
            let payload = c.bytes(payload_bytes, &name)?;
            if checked {
                let want = c.u64(&name)?;
                let mut fnv = Fnv::new();
                fnv.update(payload);
                if fnv.0 != want {
                    bail!(
                        "{}: tensor '{name}': checksum mismatch (stored {want:#018x}, \
                         computed {:#018x}) — corrupted artifact",
                        path.display(),
                        fnv.0
                    );
                }
            }
            match dtype {
                0 => {
                    let data: Vec<f32> = payload
                        .chunks_exact(4)
                        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                        .collect();
                    store.insert(&name, Tensor::from_vec(&shape, data)?);
                }
                1 => {
                    let data: Vec<i32> = payload
                        .chunks_exact(4)
                        .map(|ch| i32::from_le_bytes(ch.try_into().unwrap()))
                        .collect();
                    store.insert_i32(&name, TensorI32::from_vec(&shape, data)?);
                }
                d => bail!("{}: tensor '{name}': unknown dtype {d}", path.display()),
            }
        }
        Ok(store)
    }
}

/// Bounds-checked reader over the slurped file: every read is validated
/// against the remaining length first, so corrupt headers surface as
/// "declares N bytes but only M remain", not allocation blowups.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
    path: &'a Path,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let remain = self.buf.len() - self.off;
        if n > remain {
            bail!(
                "{}: {what} declares {n} bytes but only {remain} remain — \
                 truncated or corrupt file",
                self.path.display()
            );
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }
}

fn write_header(w: &mut impl Write, name: &str, dtype: u8, shape: &[usize]) -> Result<()> {
    w.write_all(&(name.len() as u16).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&[dtype, shape.len() as u8])?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("faquant_store_{name}_{}.fqt", std::process::id()))
    }

    #[test]
    fn roundtrip_f32_i32() {
        let mut s = TensorStore::new();
        let mut rng = Rng::new(1);
        s.insert("w.a", Tensor::randn(&mut rng, &[3, 5], 1.0));
        s.insert("w.b", Tensor::randn(&mut rng, &[7], 0.5));
        s.insert_i32(
            "tok",
            TensorI32::from_vec(&[2, 3], vec![1, -2, 3, 4, 5, 6]).unwrap(),
        );
        let p = tmp("rt");
        s.save(&p).unwrap();
        let back = TensorStore::load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.get("w.a").unwrap(), s.get("w.a").unwrap());
        assert_eq!(back.get("w.b").unwrap(), s.get("w.b").unwrap());
        assert_eq!(back.get_i32("tok").unwrap(), s.get_i32("tok").unwrap());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let s = TensorStore::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorStore::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncated_file_fails_clearly() {
        let mut s = TensorStore::new();
        let mut rng = Rng::new(9);
        s.insert("w", Tensor::randn(&mut rng, &[8, 8], 1.0));
        let p = tmp("trunc");
        s.save(&p).unwrap();
        let full = std::fs::read(&p).unwrap();
        // Cut inside the payload, inside the header, and after the magic.
        for cut in [full.len() - 5, 4 + 4 + 1, 6] {
            std::fs::write(&p, &full[..cut]).unwrap();
            let err = TensorStore::load(&p).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("remain"),
                "cut at {cut}: unexpected error '{err}'"
            );
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn oversized_header_rejected_without_allocation() {
        // Header claims a [2^40, 2^40] tensor: numel must fail via
        // checked multiplication, not attempt an absurd allocation.
        let p = tmp("huge");
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'x');
        buf.push(0); // dtype f32
        buf.push(2); // ndim
        buf.extend_from_slice(&(1u64 << 40).to_le_bytes());
        buf.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        let err = TensorStore::load(&p).unwrap_err().to_string();
        assert!(err.contains("overflow"), "unexpected error '{err}'");
        // A merely-huge (non-overflowing) claim is bounded by file length.
        let mut buf2 = Vec::new();
        buf2.extend_from_slice(MAGIC);
        buf2.extend_from_slice(&1u32.to_le_bytes());
        buf2.extend_from_slice(&1u16.to_le_bytes());
        buf2.push(b'x');
        buf2.push(0);
        buf2.push(1);
        buf2.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&p, &buf2).unwrap();
        let err = TensorStore::load(&p).unwrap_err().to_string();
        assert!(
            err.contains("truncated") || err.contains("remain"),
            "unexpected error '{err}'"
        );
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn duplicate_tensor_names_rejected() {
        // Handcraft a (legacy, checksum-less) file with two entries
        // under the same name.
        let p = tmp("dup");
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V1);
        buf.extend_from_slice(&2u32.to_le_bytes());
        for _ in 0..2 {
            buf.extend_from_slice(&1u16.to_le_bytes());
            buf.push(b'a');
            buf.push(0); // dtype f32
            buf.push(1); // ndim
            buf.extend_from_slice(&1u64.to_le_bytes());
            buf.extend_from_slice(&1.5f32.to_le_bytes());
        }
        std::fs::write(&p, &buf).unwrap();
        let err = TensorStore::load(&p).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "unexpected error '{err}'");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn payload_bit_flip_is_detected_and_names_the_tensor() {
        let mut s = TensorStore::new();
        let mut rng = Rng::new(11);
        s.insert("layer.weight", Tensor::randn(&mut rng, &[4, 4], 1.0));
        let p = tmp("flip");
        s.save(&p).unwrap();
        let mut buf = std::fs::read(&p).unwrap();
        // Flip one bit in the middle of the 64-byte payload (which
        // starts after magic + count + entry header = 4 + 4 + 2 + 12 +
        // 1 + 1 + 16 = 40 bytes), leaving every header field intact.
        let mid = 40 + 32;
        buf[mid] ^= 0x01;
        std::fs::write(&p, &buf).unwrap();
        let err = TensorStore::load(&p).unwrap_err().to_string();
        assert!(
            err.contains("checksum mismatch") && err.contains("'layer.weight'"),
            "unexpected error '{err}'"
        );
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn legacy_fqt1_files_still_load() {
        // A pre-checksum v1 file: same layout, no trailing fnv word.
        let p = tmp("v1");
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V1);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'w');
        buf.push(0); // dtype f32
        buf.push(1); // ndim
        buf.extend_from_slice(&2u64.to_le_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&(-2.5f32).to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        let back = TensorStore::load(&p).unwrap();
        assert_eq!(back.get("w").unwrap().data(), &[1.5, -2.5]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn saved_files_carry_the_v2_magic() {
        let mut s = TensorStore::new();
        s.insert("x", Tensor::from_vec(&[1], vec![1.0]).unwrap());
        let p = tmp("magic2");
        s.save(&p).unwrap();
        let buf = std::fs::read(&p).unwrap();
        assert_eq!(&buf[..4], MAGIC);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scalar_shape_roundtrip() {
        let mut s = TensorStore::new();
        s.insert("step", Tensor::from_vec(&[], vec![42.0]).unwrap());
        let p = tmp("scalar");
        s.save(&p).unwrap();
        let back = TensorStore::load(&p).unwrap();
        assert_eq!(back.get("step").unwrap().data(), &[42.0]);
        std::fs::remove_file(p).ok();
    }
}
