//! `.fqt` binary tensor store (S2): named-tensor checkpoints.
//!
//! Little-endian layout:
//! ```text
//! magic   b"FQT1"
//! u32     n_entries
//! entry*: u16 name_len | name utf8 | u8 dtype (0=f32, 1=i32)
//!         u8 ndim | u64 dims[ndim] | raw LE payload
//! ```
//! Used for model checkpoints (rust writes, rust reads), quantized model
//! bundles, and calibration stat dumps. Python never reads these — the
//! rust coordinator uploads tensors to PJRT directly.

use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FQT1";

/// An ordered collection of named tensors.
#[derive(Default, Clone, Debug)]
pub struct TensorStore {
    f32s: BTreeMap<String, Tensor>,
    i32s: BTreeMap<String, TensorI32>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.f32s.insert(name.to_string(), t);
    }

    pub fn insert_i32(&mut self, name: &str, t: TensorI32) {
        self.i32s.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.f32s
            .get(name)
            .with_context(|| format!("tensor '{name}' not in store"))
    }

    pub fn get_i32(&self, name: &str) -> Result<&TensorI32> {
        self.i32s
            .get(name)
            .with_context(|| format!("i32 tensor '{name}' not in store"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.f32s.contains_key(name) || self.i32s.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.f32s
            .keys()
            .chain(self.i32s.keys())
            .map(|s| s.as_str())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.f32s.len() + self.i32s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.len() as u32).to_le_bytes())?;
        for (name, t) in &self.f32s {
            write_header(&mut w, name, 0, t.shape())?;
            for v in t.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        for (name, t) in &self.i32s {
            write_header(&mut w, name, 1, t.shape())?;
            for v in t.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let n = read_u32(&mut r)? as usize;
        let mut store = Self::new();
        for _ in 0..n {
            let name_len = read_u16(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf8")?;
            let dtype = read_u8(&mut r)?;
            let ndim = read_u8(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let numel: usize = shape.iter().product();
            match dtype {
                0 => {
                    let mut data = vec![0f32; numel];
                    let mut buf = vec![0u8; numel * 4];
                    r.read_exact(&mut buf)?;
                    for (i, c) in buf.chunks_exact(4).enumerate() {
                        data[i] = f32::from_le_bytes(c.try_into().unwrap());
                    }
                    store.insert(&name, Tensor::from_vec(&shape, data)?);
                }
                1 => {
                    let mut data = vec![0i32; numel];
                    let mut buf = vec![0u8; numel * 4];
                    r.read_exact(&mut buf)?;
                    for (i, c) in buf.chunks_exact(4).enumerate() {
                        data[i] = i32::from_le_bytes(c.try_into().unwrap());
                    }
                    store.insert_i32(&name, TensorI32::from_vec(&shape, data)?);
                }
                d => bail!("unknown dtype {d}"),
            }
        }
        Ok(store)
    }
}

fn write_header(w: &mut impl Write, name: &str, dtype: u8, shape: &[usize]) -> Result<()> {
    w.write_all(&(name.len() as u16).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&[dtype, shape.len() as u8])?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("faquant_store_{name}_{}.fqt", std::process::id()))
    }

    #[test]
    fn roundtrip_f32_i32() {
        let mut s = TensorStore::new();
        let mut rng = Rng::new(1);
        s.insert("w.a", Tensor::randn(&mut rng, &[3, 5], 1.0));
        s.insert("w.b", Tensor::randn(&mut rng, &[7], 0.5));
        s.insert_i32(
            "tok",
            TensorI32::from_vec(&[2, 3], vec![1, -2, 3, 4, 5, 6]).unwrap(),
        );
        let p = tmp("rt");
        s.save(&p).unwrap();
        let back = TensorStore::load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.get("w.a").unwrap(), s.get("w.a").unwrap());
        assert_eq!(back.get("w.b").unwrap(), s.get("w.b").unwrap());
        assert_eq!(back.get_i32("tok").unwrap(), s.get_i32("tok").unwrap());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let s = TensorStore::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorStore::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scalar_shape_roundtrip() {
        let mut s = TensorStore::new();
        s.insert("step", Tensor::from_vec(&[], vec![42.0]).unwrap());
        let p = tmp("scalar");
        s.save(&p).unwrap();
        let back = TensorStore::load(&p).unwrap();
        assert_eq!(back.get("step").unwrap().data(), &[42.0]);
        std::fs::remove_file(p).ok();
    }
}
