//! TOML-lite parser: the subset of TOML the run configs use.
//!
//! Supported: `[section]` headers, `key = value` with string ("..."),
//! integer, float, boolean values, `#` comments, blank lines. No nesting,
//! arrays-of-tables, or multi-line strings — config files stay flat.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Parsed document: (section, key) -> value. Keys before any section
/// header live in section "".
#[derive(Default, Debug)]
pub struct TomlDoc {
    map: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s.clone()),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

fn parse_value(raw: &str, line_no: usize) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            bail!("line {line_no}: unterminated string");
        }
        return Ok(TomlValue::Str(raw[1..raw.len() - 1].to_string()));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {line_no}: cannot parse value '{raw}'")
}

pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        // Strip comments outside strings (values here never contain '#').
        let line = match raw_line.find('#') {
            Some(pos) if !raw_line[..pos].contains('"') || raw_line[..pos].matches('"').count() % 2 == 0 => {
                &raw_line[..pos]
            }
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let Some(name) = stripped.strip_suffix(']') else {
                bail!("line {line_no}: malformed section header '{line}'");
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {line_no}: expected 'key = value', got '{line}'");
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {line_no}: empty key");
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        doc.map.insert((section.clone(), key.to_string()), value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let doc = parse_toml(
            "top = 1\n[a]\ns = \"hi\"\ni = -3\nf = 2.5\nb = true\n# comment\n[b]\nx = 0 # trailing\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s").as_deref(), Some("hi"));
        assert_eq!(doc.get_int("a", "i"), Some(-3));
        assert_eq!(doc.get_float("a", "f"), Some(2.5));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_int("b", "x"), Some(0));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse_toml("[q]\ngamma = 1\n").unwrap();
        assert_eq!(doc.get_float("q", "gamma"), Some(1.0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("k = \"unterminated\n").is_err());
        assert!(parse_toml("k = what\n").is_err());
    }

    #[test]
    fn wrong_type_returns_none() {
        let doc = parse_toml("[a]\nx = 5\n").unwrap();
        assert_eq!(doc.get_str("a", "x"), None);
        assert_eq!(doc.get_bool("a", "x"), None);
    }
}
