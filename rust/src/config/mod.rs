//! Configuration system (S13): model presets, quantization settings, run
//! configuration, and a TOML-lite file format (no serde/toml offline).

mod toml_lite;

pub use toml_lite::{parse_toml, TomlDoc, TomlValue};

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Transformer architecture preset. MUST mirror python/compile/model.py
/// `CONFIGS` — the manifest cross-checks this at registry load.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
}

impl ModelConfig {
    pub fn preset(name: &str) -> Result<Self> {
        let (n_layer, d_model, n_head, d_ff, vocab) = match name {
            "pico" => (2, 64, 2, 256, 256),
            "nano" => (4, 128, 4, 512, 384),
            "tiny" => (6, 192, 6, 768, 384),
            "small" => (8, 256, 8, 1024, 512),
            other => bail!("unknown model preset '{other}'"),
        };
        Ok(Self {
            name: name.to_string(),
            n_layer,
            d_model,
            n_head,
            d_ff,
            vocab,
            seq: 128,
            batch: 4,
        })
    }

    pub fn all_presets() -> Vec<&'static str> {
        vec!["pico", "nano", "tiny", "small"]
    }

    /// Total parameter count (all tensors in the canonical spec).
    pub fn param_count(&self) -> usize {
        crate::model::param_specs(self)
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

/// Quantization method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full-precision reference (no quantization).
    Fp,
    /// Round-to-nearest baseline: no activation awareness.
    Rtn,
    /// AWQ baseline: current-layer activation scale + alpha grid search.
    Awq,
    /// The paper: future-aware fused activation scale (Sec. 2.2).
    Faq,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fp" | "fp16" | "fp32" => Method::Fp,
            "rtn" => Method::Rtn,
            "awq" => Method::Awq,
            "faq" => Method::Faq,
            other => bail!("unknown method '{other}' (fp|rtn|awq|faq)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "FP",
            Method::Rtn => "RTN",
            Method::Awq => "AWQ",
            Method::Faq => "FAQ",
        }
    }
}

/// Quantization hyperparameters (paper Sec. 2.2 + Sec. 3.1).
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub method: Method,
    /// Bit width b (3 or 4 in the paper's evaluation).
    pub bits: u32,
    /// Quantization group size along the input-channel dim.
    pub group: usize,
    /// Alpha grid for the scale exponent search (AWQ Sec. 3.1: 20 points).
    pub alpha_grid: usize,
    /// FAQ fusion factor gamma (pre-searched 0.85).
    pub gamma: f32,
    /// FAQ preview window length j (pre-searched 3).
    pub window: usize,
    /// Full greedy search over (alpha, j, gamma) instead of the
    /// pre-searched configuration (paper eq. 8; expensive).
    pub full_search: bool,
    /// Use layer-wise preview (single future layer at distance `window`)
    /// instead of the window-wise soft average — ablation mode.
    pub layerwise_preview: bool,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self {
            method: Method::Faq,
            bits: 3,
            group: 64,
            alpha_grid: 20,
            gamma: 0.85,
            window: 3,
            full_search: false,
            layerwise_preview: false,
        }
    }
}

impl QuantConfig {
    pub fn with_method(method: Method) -> Self {
        Self {
            method,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(2..=8).contains(&self.bits) {
            bail!("bits={} out of range [2, 8]", self.bits);
        }
        if self.group == 0 || self.group % 8 != 0 {
            bail!("group={} must be a positive multiple of 8", self.group);
        }
        if !(0.0..=1.0).contains(&self.gamma) {
            bail!("gamma={} must be in [0, 1]", self.gamma);
        }
        if self.window == 0 {
            bail!("window must be >= 1");
        }
        if self.alpha_grid < 2 {
            bail!("alpha_grid must be >= 2");
        }
        Ok(())
    }
}

/// Top-level run configuration (CLI flags / TOML file).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub quant: QuantConfig,
    /// Number of calibration sequences N (Table 3 varies this).
    pub calib_seqs: usize,
    /// Calibration corpus seed (distinct seeds = disjoint samples).
    pub calib_seed: u64,
    /// Training steps for the checkpoint (0 = random init).
    pub train_steps: usize,
    /// Number of evaluation sequences per corpus.
    pub eval_seqs: usize,
    /// Items per zero-shot suite.
    pub task_items: usize,
    /// artifacts/ directory.
    pub artifacts_dir: String,
    /// runs/ directory (checkpoints, reports).
    pub runs_dir: String,
}

impl RunConfig {
    pub fn new(model: &str) -> Result<Self> {
        Ok(Self {
            model: ModelConfig::preset(model)?,
            quant: QuantConfig::default(),
            calib_seqs: 64,
            calib_seed: 1234,
            train_steps: 200,
            eval_seqs: 32,
            task_items: 64,
            artifacts_dir: "artifacts".into(),
            runs_dir: "runs".into(),
        })
    }

    /// Load overrides from a TOML-lite file (sections [model], [quant], [run]).
    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let doc = parse_toml(&text)?;
        if let Some(name) = doc.get_str("model", "preset") {
            self.model = ModelConfig::preset(&name)?;
        }
        if let Some(m) = doc.get_str("quant", "method") {
            self.quant.method = Method::parse(&m)?;
        }
        if let Some(b) = doc.get_int("quant", "bits") {
            self.quant.bits = b as u32;
        }
        if let Some(g) = doc.get_int("quant", "group") {
            self.quant.group = g as usize;
        }
        if let Some(g) = doc.get_float("quant", "gamma") {
            self.quant.gamma = g as f32;
        }
        if let Some(w) = doc.get_int("quant", "window") {
            self.quant.window = w as usize;
        }
        if let Some(f) = doc.get_bool("quant", "full_search") {
            self.quant.full_search = f;
        }
        if let Some(n) = doc.get_int("run", "calib_seqs") {
            self.calib_seqs = n as usize;
        }
        if let Some(n) = doc.get_int("run", "train_steps") {
            self.train_steps = n as usize;
        }
        if let Some(n) = doc.get_int("run", "eval_seqs") {
            self.eval_seqs = n as usize;
        }
        if let Some(s) = doc.get_str("run", "artifacts_dir") {
            self.artifacts_dir = s;
        }
        if let Some(s) = doc.get_str("run", "runs_dir") {
            self.runs_dir = s;
        }
        self.quant.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        for name in ModelConfig::all_presets() {
            let cfg = ModelConfig::preset(name).unwrap();
            assert_eq!(cfg.d_model % cfg.n_head, 0);
            assert!(cfg.param_count() > 0);
        }
        assert!(ModelConfig::preset("mega").is_err());
    }

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("rtn", Method::Rtn),
            ("AWQ", Method::Awq),
            ("faq", Method::Faq),
            ("fp16", Method::Fp),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("gptq").is_err());
    }

    #[test]
    fn quant_validation() {
        let mut q = QuantConfig::default();
        q.validate().unwrap();
        q.bits = 1;
        assert!(q.validate().is_err());
        q.bits = 4;
        q.gamma = 1.5;
        assert!(q.validate().is_err());
    }

    #[test]
    fn run_config_from_file() {
        let p = std::env::temp_dir().join(format!("faquant_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &p,
            "[model]\npreset = \"nano\"\n[quant]\nmethod = \"awq\"\nbits = 4\ngamma = 0.7\n[run]\ncalib_seqs = 16\n",
        )
        .unwrap();
        let mut rc = RunConfig::new("pico").unwrap();
        rc.apply_file(&p).unwrap();
        assert_eq!(rc.model.name, "nano");
        assert_eq!(rc.quant.method, Method::Awq);
        assert_eq!(rc.quant.bits, 4);
        assert!((rc.quant.gamma - 0.7).abs() < 1e-6);
        assert_eq!(rc.calib_seqs, 16);
        std::fs::remove_file(p).ok();
    }
}
