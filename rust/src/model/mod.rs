//! Model parameter layout, initialization, and checkpoints (S4).
//!
//! The canonical flat parameter order mirrors python/compile/model.py
//! `param_specs` exactly — the runtime registry cross-checks it against
//! the artifact manifest at load.

use crate::config::ModelConfig;
use crate::store::TensorStore;
use crate::tensor::{Rng, Tensor};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The four quantizable linear roles per block, in block order.
pub const ROLES: [&str; 4] = ["qkv", "o", "up", "down"];

/// [n_in, n_out] of a role's weight.
pub fn role_shape(cfg: &ModelConfig, role: &str) -> (usize, usize) {
    let (d, ff) = (cfg.d_model, cfg.d_ff);
    match role {
        "qkv" => (d, 3 * d),
        "o" => (d, d),
        "up" => (d, ff),
        "down" => (ff, d),
        other => panic!("unknown role {other}"),
    }
}

/// Weight tensor name of (block, role), e.g. `blk2.w_up`.
pub fn role_param(block: usize, role: &str) -> String {
    format!("blk{block}.w_{role}")
}

/// Canonical flat parameter spec: (name, shape) in artifact argument order.
pub fn param_specs(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d_model;
    let mut specs: Vec<(String, Vec<usize>)> = vec![
        ("tok_emb".into(), vec![cfg.vocab, d]),
        ("pos_emb".into(), vec![cfg.seq, d]),
    ];
    for b in 0..cfg.n_layer {
        specs.push((format!("blk{b}.ln1_g"), vec![d]));
        let (n, m) = role_shape(cfg, "qkv");
        specs.push((format!("blk{b}.w_qkv"), vec![n, m]));
        let (n, m) = role_shape(cfg, "o");
        specs.push((format!("blk{b}.w_o"), vec![n, m]));
        specs.push((format!("blk{b}.ln2_g"), vec![d]));
        let (n, m) = role_shape(cfg, "up");
        specs.push((format!("blk{b}.w_up"), vec![n, m]));
        let (n, m) = role_shape(cfg, "down");
        specs.push((format!("blk{b}.w_down"), vec![n, m]));
    }
    specs.push(("lnf_g".into(), vec![d]));
    specs.push(("w_head".into(), vec![d, cfg.vocab]));
    specs
}

/// A model's full parameter set in canonical order.
#[derive(Clone, Debug)]
pub struct Params {
    pub cfg: ModelConfig,
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// Random init: normals scaled by 1/sqrt(fan_in) for linears, small
    /// for embeddings, ones for norm gains — matches test_model.py.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tensors = param_specs(cfg)
            .iter()
            .map(|(name, shape)| {
                if name.ends_with("_g") {
                    Tensor::ones(shape)
                } else if name.contains("emb") {
                    Tensor::randn(&mut rng, shape, 0.08)
                } else {
                    let std = 1.0 / (shape[0] as f32).sqrt();
                    Tensor::randn(&mut rng, shape, std)
                }
            })
            .collect();
        Self {
            cfg: cfg.clone(),
            tensors,
        }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let idx = self.index_of(name)?;
        Ok(&self.tensors[idx])
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let idx = self.index_of(name)?;
        if self.tensors[idx].shape() != t.shape() {
            bail!(
                "set {name}: shape {:?} != expected {:?}",
                t.shape(),
                self.tensors[idx].shape()
            );
        }
        self.tensors[idx] = t;
        Ok(())
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        param_specs(&self.cfg)
            .iter()
            .position(|(n, _)| n == name)
            .with_context(|| format!("unknown param '{name}'"))
    }

    /// Weight of (block, role).
    pub fn role_weight(&self, block: usize, role: &str) -> Result<&Tensor> {
        self.get(&role_param(block, role))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut store = TensorStore::new();
        for ((name, _), t) in param_specs(&self.cfg).iter().zip(&self.tensors) {
            store.insert(name, t.clone());
        }
        store.insert(
            "__meta.n_layer",
            Tensor::from_vec(&[], vec![self.cfg.n_layer as f32])?,
        );
        store.save(path)
    }

    pub fn load(cfg: &ModelConfig, path: &Path) -> Result<Self> {
        let store = TensorStore::load(path)?;
        let tensors = param_specs(cfg)
            .iter()
            .map(|(name, shape)| {
                let t = store.get(name)?;
                if t.shape() != &shape[..] {
                    bail!(
                        "checkpoint {name}: shape {:?} != expected {:?}",
                        t.shape(),
                        shape
                    );
                }
                Ok(t.clone())
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            cfg: cfg.clone(),
            tensors,
        })
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("pico").unwrap()
    }

    #[test]
    fn spec_count_matches_formula() {
        let c = cfg();
        assert_eq!(param_specs(&c).len(), 2 + 6 * c.n_layer + 2);
    }

    #[test]
    fn init_shapes_and_norm_gains() {
        let p = Params::init(&cfg(), 1);
        assert_eq!(p.tensors.len(), param_specs(&cfg()).len());
        let g = p.get("blk0.ln1_g").unwrap();
        assert!(g.data().iter().all(|&x| x == 1.0));
        let (n, m) = role_shape(&cfg(), "qkv");
        assert_eq!(p.role_weight(0, "qkv").unwrap().shape(), &[n, m]);
    }

    #[test]
    fn save_load_roundtrip() {
        let c = cfg();
        let p = Params::init(&c, 2);
        let path = std::env::temp_dir().join(format!("faquant_ckpt_{}.fqt", std::process::id()));
        p.save(&path).unwrap();
        let q = Params::load(&c, &path).unwrap();
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_wrong_config() {
        let p = Params::init(&cfg(), 3);
        let path = std::env::temp_dir().join(format!("faquant_ckpt2_{}.fqt", std::process::id()));
        p.save(&path).unwrap();
        let nano = ModelConfig::preset("nano").unwrap();
        assert!(Params::load(&nano, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn set_checks_shape() {
        let mut p = Params::init(&cfg(), 4);
        assert!(p.set("lnf_g", Tensor::zeros(&[999])).is_err());
        let d = cfg().d_model;
        p.set("lnf_g", Tensor::zeros(&[d])).unwrap();
        assert_eq!(p.get("lnf_g").unwrap().sum(), 0.0);
    }
}
