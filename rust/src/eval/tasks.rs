//! Synthetic zero-shot suites — structure-matched stand-ins for the
//! paper's PIQA / ARC-e / ARC-c / BoolQ / HellaSwag / WinoGrande columns.
//!
//! Every item is K fixed-length token sequences sharing a context prefix
//! and differing in the final `cont_len` tokens; option 0..K-1 contains
//! exactly one "true" continuation (drawn from the corpus generator's
//! actual dynamics) among distractors whose *hardness* mirrors the
//! original benchmark: easy suites use uniform word salad, hard suites
//! use locally-plausible bigram text that ignores the context.
//!
//! What Table 1's accuracy columns measure is "does quantization preserve
//! the model's ranking decisions" — these suites measure exactly that
//! under the same length-normalized logprob rule.

use crate::config::ModelConfig;
use crate::corpus::{CorpusKind, Generator, Tokenizer};
use crate::tensor::Rng;
use anyhow::{bail, Result};

/// Distractor construction mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hardness {
    /// Plausible text with every third token corrupted to a random one —
    /// clearly worse than the truth but not trivially so (keeps the
    /// "easy" suites off the 100% ceiling so quantization deltas show).
    Salad,
    /// Bigram-plausible text disconnected from the context.
    Plausible,
    /// The true continuation with one adjacent token pair swapped — the
    /// subtlest corruption (binary yes/no-style discrimination).
    Shuffled,
}

/// Static description of one suite.
#[derive(Clone, Debug)]
pub struct SuiteSpec {
    pub name: &'static str,
    /// Paper column this suite stands in for.
    pub paper_column: &'static str,
    pub n_options: usize,
    pub cont_len: usize,
    pub hardness: Hardness,
    pub seed: u64,
}

/// All six suites, mirroring Table 1's column order.
pub fn suite_specs() -> Vec<SuiteSpec> {
    vec![
        SuiteSpec {
            name: "arc_challenge",
            paper_column: "arc_challenge",
            n_options: 4,
            cont_len: 8,
            hardness: Hardness::Plausible,
            seed: 701,
        },
        SuiteSpec {
            name: "hellaswag",
            paper_column: "hellaswag",
            n_options: 4,
            cont_len: 24,
            hardness: Hardness::Plausible,
            seed: 702,
        },
        SuiteSpec {
            name: "winogrande",
            paper_column: "winogrande",
            n_options: 2,
            cont_len: 6,
            hardness: Hardness::Plausible,
            seed: 703,
        },
        SuiteSpec {
            name: "arc_easy",
            paper_column: "arc_easy",
            n_options: 4,
            cont_len: 8,
            hardness: Hardness::Salad,
            seed: 704,
        },
        SuiteSpec {
            name: "boolq",
            paper_column: "boolq",
            n_options: 2,
            cont_len: 6,
            hardness: Hardness::Shuffled,
            seed: 705,
        },
        SuiteSpec {
            name: "piqa",
            paper_column: "piqa",
            n_options: 2,
            cont_len: 12,
            hardness: Hardness::Salad,
            seed: 706,
        },
    ]
}

/// One scored item: K full-length token rows, one of which is correct.
#[derive(Clone, Debug)]
pub struct TaskItem {
    /// Each option is a full sequence of exactly `cfg.seq` token ids
    /// (shared context + candidate continuation).
    pub options: Vec<Vec<i32>>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub spec: SuiteSpec,
    pub items: Vec<TaskItem>,
}

/// Build one suite of `n_items` items.
pub fn build_suite(
    cfg: &ModelConfig,
    tok: &Tokenizer,
    spec: &SuiteSpec,
    n_items: usize,
) -> Result<TaskSuite> {
    let t = cfg.seq;
    if spec.cont_len + 8 > t {
        bail!("cont_len {} too long for seq {t}", spec.cont_len);
    }
    let ctx_len = t - spec.cont_len;
    let mut gen = Generator::new(CorpusKind::SynthWiki, spec.seed);
    let mut distract_gen = Generator::new(CorpusKind::SynthWiki, spec.seed ^ 0xD15);
    let mut rng = Rng::new(spec.seed.wrapping_mul(31));
    // Distractors are built in *token* space so they can never degenerate
    // into <unk> runs (the tokenizer vocab may be smaller than the
    // generator lexicon): salad draws uniform in-vocab ids, plausible
    // takes real-corpus token chunks disconnected from the context.
    let distract_ids: Vec<i32> = tok.encode(&distract_gen.text(64 * spec.cont_len + 512));
    let vocab_used = tok.vocab_size() as i32;
    let mut distract_pos = 0usize;

    let mut items = Vec::with_capacity(n_items);
    while items.len() < n_items {
        // Context + true continuation come from one coherent stream.
        let stream_words = spec.cont_len + 3 * ctx_len;
        let text = gen.text(stream_words);
        let ids = tok.encode(&text);
        if ids.len() < ctx_len + spec.cont_len {
            continue;
        }
        let ctx: Vec<i32> = ids[..ctx_len].to_vec();
        let true_cont: Vec<i32> = ids[ctx_len..ctx_len + spec.cont_len].to_vec();

        let answer = rng.below(spec.n_options);
        let mut options = Vec::with_capacity(spec.n_options);
        for k in 0..spec.n_options {
            let cont = if k == answer {
                true_cont.clone()
            } else {
                let mut take_chunk = |len: usize| {
                    if distract_pos + len > distract_ids.len() {
                        distract_pos = 0;
                    }
                    let chunk = distract_ids[distract_pos..distract_pos + len].to_vec();
                    distract_pos += len;
                    chunk
                };
                match spec.hardness {
                    Hardness::Salad => {
                        let mut c = take_chunk(spec.cont_len);
                        for (idx, tok_id) in c.iter_mut().enumerate() {
                            if idx % 3 == 0 {
                                *tok_id = 2 + rng.below((vocab_used - 2) as usize) as i32;
                            }
                        }
                        c
                    }
                    Hardness::Plausible => take_chunk(spec.cont_len),
                    Hardness::Shuffled => {
                        let mut c = true_cont.clone();
                        // Swap one adjacent differing pair; if the whole
                        // continuation is a constant run, corrupt one slot.
                        let start = rng.below(c.len().saturating_sub(1).max(1));
                        let pos = (start..c.len() - 1)
                            .chain(0..start)
                            .find(|&i| c[i] != c[i + 1]);
                        match pos {
                            Some(i) => c.swap(i, i + 1),
                            None => {
                                let i = rng.below(c.len());
                                c[i] = 2 + rng.below((vocab_used - 2) as usize) as i32;
                            }
                        }
                        c
                    }
                }
            };
            let mut row = ctx.clone();
            row.extend_from_slice(&cont);
            debug_assert_eq!(row.len(), t);
            options.push(row);
        }
        items.push(TaskItem { options, answer });
    }
    Ok(TaskSuite {
        spec: spec.clone(),
        items,
    })
}

/// Build all six suites.
pub fn task_suites(cfg: &ModelConfig, tok: &Tokenizer, n_items: usize) -> Result<Vec<TaskSuite>> {
    suite_specs()
        .iter()
        .map(|s| build_suite(cfg, tok, s, n_items))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::canonical_tokenizer;

    #[test]
    fn suites_have_exact_shapes() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let tok = canonical_tokenizer(&cfg);
        for spec in suite_specs() {
            let suite = build_suite(&cfg, &tok, &spec, 5).unwrap();
            assert_eq!(suite.items.len(), 5, "{}", spec.name);
            for item in &suite.items {
                assert_eq!(item.options.len(), spec.n_options);
                assert!(item.answer < spec.n_options);
                for opt in &item.options {
                    assert_eq!(opt.len(), cfg.seq);
                    assert!(opt.iter().all(|&i| (i as usize) < cfg.vocab));
                }
            }
        }
    }

    #[test]
    fn options_share_context_differ_in_continuation() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let tok = canonical_tokenizer(&cfg);
        let spec = &suite_specs()[0];
        let suite = build_suite(&cfg, &tok, spec, 3).unwrap();
        for item in &suite.items {
            let ctx_len = cfg.seq - spec.cont_len;
            let ctx0 = &item.options[0][..ctx_len];
            for opt in &item.options[1..] {
                assert_eq!(&opt[..ctx_len], ctx0);
            }
            // At least one distractor differs from the answer tail.
            let ans_tail = &item.options[item.answer][ctx_len..];
            assert!(item
                .options
                .iter()
                .enumerate()
                .any(|(k, o)| k != item.answer && &o[ctx_len..] != ans_tail));
        }
    }

    #[test]
    fn deterministic_construction() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let tok = canonical_tokenizer(&cfg);
        let spec = &suite_specs()[2];
        let a = build_suite(&cfg, &tok, spec, 4).unwrap();
        let b = build_suite(&cfg, &tok, spec, 4).unwrap();
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.answer, y.answer);
            assert_eq!(x.options, y.options);
        }
    }

    #[test]
    fn six_suites_match_paper_columns() {
        let names: Vec<&str> = suite_specs().iter().map(|s| s.paper_column).collect();
        assert_eq!(
            names,
            vec!["arc_challenge", "hellaswag", "winogrande", "arc_easy", "boolq", "piqa"]
        );
    }
}
