//! Paper-table generators: the shared engine behind `faquant table*`
//! subcommands and the `rust/benches/table*` bench targets.
//!
//! Each function regenerates one table of the paper's evaluation section
//! with our models/corpora (DESIGN.md §5) and returns a markdown
//! [`Table`]. Checkpoints and calibration captures are computed once per
//! model and shared across methods, exactly like the paper's protocol.

use crate::benchkit::{f4, Table};
use crate::calib::CalibStats;
use crate::config::{Method, RunConfig};
use crate::coordinator::Pipeline;
use crate::eval::{canonical_tokenizer, eval_all, EvalRow};
use crate::model::Params;
use crate::runtime::Runtime;
use crate::tensor::mean_std;
use anyhow::Result;

/// Methods in the paper's row order.
pub const METHODS: [Method; 4] = [Method::Fp, Method::Rtn, Method::Awq, Method::Faq];

fn eval_params(
    rt: &Runtime,
    cfg: &RunConfig,
    params: &Params,
) -> Result<EvalRow> {
    let tok = canonical_tokenizer(&cfg.model);
    eval_all(rt, &cfg.model, params, &tok, cfg.eval_seqs, cfg.task_items)
}

/// Run all four methods for one model, reusing checkpoint + calibration.
pub fn method_rows(
    rt: &Runtime,
    base: &RunConfig,
    methods: &[Method],
) -> Result<Vec<(Method, EvalRow)>> {
    let pipe = Pipeline::new(rt, base.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    let mut rows = Vec::new();
    for &m in methods {
        let row = if m == Method::Fp {
            eval_params(rt, base, &params)?
        } else {
            let mut cfg = base.clone();
            cfg.quant.method = m;
            let pipe_m = Pipeline::new(rt, cfg.clone());
            let (qm, _) = pipe_m.quantize(&params, Some(&calib))?;
            eval_params(rt, &cfg, &qm.fq_params)?
        };
        rows.push((m, row));
    }
    Ok(rows)
}

/// Table 1: the main grid — models x methods x (2 PPL + 6 accuracy).
pub fn table1(rt: &Runtime, models: &[&str], base: &RunConfig) -> Result<Table> {
    let mut t = Table::new(
        "Table 1 — perplexity (down) and accuracy (up), weight-only 3-bit",
        &[
            "LLM", "Quant", "wikitext2", "c4", "arc_challenge", "hellaswag",
            "winogrande", "arc_easy", "boolq", "piqa",
        ],
    );
    for model in models {
        let mut cfg = base.clone();
        cfg.model = crate::config::ModelConfig::preset(model)?;
        for (m, row) in method_rows(rt, &cfg, &METHODS)? {
            let mut cells = vec![
                model.to_string(),
                m.name().to_string(),
                f4(row.ppl_wiki),
                f4(row.ppl_c4),
            ];
            for (_, acc) in &row.accs {
                cells.push(f4(*acc));
            }
            t.row(cells);
        }
    }
    Ok(t)
}

/// Table 2: 3-bit vs 4-bit boolq accuracy.
pub fn table2(rt: &Runtime, models: &[&str], base: &RunConfig) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — boolq accuracy at 3-bit vs 4-bit",
        &["LLM", "Quant", "3bit", "4bit"],
    );
    for model in models {
        let mut cfg = base.clone();
        cfg.model = crate::config::ModelConfig::preset(model)?;
        let mut per_method: Vec<(Method, Vec<f32>)> =
            METHODS.iter().map(|&m| (m, Vec::new())).collect();
        for bits in [3u32, 4] {
            let mut c = cfg.clone();
            c.quant.bits = bits;
            for (i, (m, row)) in method_rows(rt, &c, &METHODS)?.into_iter().enumerate() {
                debug_assert_eq!(per_method[i].0, m);
                let boolq = row
                    .accs
                    .iter()
                    .find(|(n, _)| n == "boolq")
                    .map(|(_, a)| *a)
                    .unwrap_or(f32::NAN);
                per_method[i].1.push(boolq);
            }
        }
        for (m, accs) in per_method {
            t.row(vec![
                model.to_string(),
                m.name().to_string(),
                f4(accs[0]),
                f4(accs[1]),
            ]);
        }
    }
    Ok(t)
}

/// Table 3: calibration-set-size robustness, AWQ vs FAQ.
///
/// For each N, the calibration sample is drawn with a distinct seed
/// (disjoint biased samples); the paper reports per-N PPL plus mean/std
/// across N — lower std = more robust to calibration bias.
pub fn table3(rt: &Runtime, model: &str, base: &RunConfig, ns: &[usize]) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — calibration-size robustness (AWQ vs FAQ)",
        &["Model", "Method", "N", "wikitext2", "c4"],
    );
    let mut cfg = base.clone();
    cfg.model = crate::config::ModelConfig::preset(model)?;
    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;

    for &method in &[Method::Awq, Method::Faq] {
        let mut wikis = Vec::new();
        let mut c4s = Vec::new();
        for (i, &n) in ns.iter().enumerate() {
            let mut c = cfg.clone();
            c.quant.method = method;
            c.calib_seqs = n;
            c.calib_seed = 100 + i as u64; // disjoint samples per N
            let pipe_n = Pipeline::new(rt, c.clone());
            let (calib, _) = pipe_n.calibrate(&params)?;
            let (qm, _) = pipe_n.quantize(&params, Some(&calib))?;
            // Table 3 reports perplexity only — skip the task suites.
            let tok = canonical_tokenizer(&c.model);
            let wiki = crate::eval::perplexity(
                rt, &c.model, &qm.fq_params, &tok,
                crate::corpus::CorpusKind::SynthWiki, c.eval_seqs,
            )?;
            let c4 = crate::eval::perplexity(
                rt, &c.model, &qm.fq_params, &tok,
                crate::corpus::CorpusKind::SynthC4, c.eval_seqs,
            )?;
            wikis.push(wiki);
            c4s.push(c4);
            t.row(vec![
                model.to_string(),
                method.name().to_string(),
                n.to_string(),
                f4(wiki),
                f4(c4),
            ]);
        }
        let (mw, sw) = mean_std(&wikis);
        let (mc, sc) = mean_std(&c4s);
        t.row(vec![
            model.to_string(),
            method.name().to_string(),
            "Mean".into(),
            f4(mw),
            f4(mc),
        ]);
        t.row(vec![
            model.to_string(),
            method.name().to_string(),
            "Std".into(),
            f4(sw),
            f4(sc),
        ]);
    }
    Ok(t)
}

/// Hyperparameter ablation: sweep gamma at fixed window (paper §3.1's
/// pre-search, regenerated).
pub fn ablation_gamma(
    rt: &Runtime,
    model: &str,
    base: &RunConfig,
    gammas: &[f32],
) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — FAQ fusion factor gamma (window = 3)",
        &["Model", "gamma", "wikitext2", "c4", "mean recon loss"],
    );
    let mut cfg = base.clone();
    cfg.model = crate::config::ModelConfig::preset(model)?;
    cfg.quant.method = Method::Faq;
    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    for &g in gammas {
        let mut c = cfg.clone();
        c.quant.gamma = g;
        let pipe_g = Pipeline::new(rt, c.clone());
        let (qm, _) = pipe_g.quantize(&params, Some(&calib))?;
        let row = eval_params(rt, &c, &qm.fq_params)?;
        t.row(vec![
            model.to_string(),
            format!("{g:.2}"),
            f4(row.ppl_wiki),
            f4(row.ppl_c4),
            format!("{:.5e}", qm.mean_loss()),
        ]);
    }
    Ok(t)
}

/// Hyperparameter ablation: sweep window length at fixed gamma, plus the
/// layer-wise preview variant (paper Sec. 2.2's two preview modes).
pub fn ablation_window(
    rt: &Runtime,
    model: &str,
    base: &RunConfig,
    windows: &[usize],
) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — FAQ preview window (gamma = 0.85) + layer-wise variant",
        &["Model", "preview", "window", "wikitext2", "c4", "mean recon loss"],
    );
    let mut cfg = base.clone();
    cfg.model = crate::config::ModelConfig::preset(model)?;
    cfg.quant.method = Method::Faq;
    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    for &layerwise in &[false, true] {
        for &w in windows {
            let mut c = cfg.clone();
            c.quant.window = w;
            c.quant.layerwise_preview = layerwise;
            let pipe_w = Pipeline::new(rt, c.clone());
            let (qm, _) = pipe_w.quantize(&params, Some(&calib))?;
            let row = eval_params(rt, &c, &qm.fq_params)?;
            t.row(vec![
                model.to_string(),
                if layerwise { "layer-wise" } else { "window-wise" }.to_string(),
                w.to_string(),
                f4(row.ppl_wiki),
                f4(row.ppl_c4),
                format!("{:.5e}", qm.mean_loss()),
            ]);
        }
    }
    Ok(t)
}

/// Shared quick profile used by table3/ablation benches.
pub fn shared_calib(
    rt: &Runtime,
    cfg: &RunConfig,
) -> Result<(Params, CalibStats)> {
    let pipe = Pipeline::new(rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    Ok((params, calib))
}
