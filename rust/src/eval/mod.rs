//! Evaluation harness (S11): perplexity + synthetic zero-shot suites.
//!
//! Perplexity runs the `fwd_logits` artifact over fixed-shape eval
//! batches and computes token-level cross-entropy host-side. The zero-shot
//! suites are structure-matched stand-ins for the paper's task list
//! (DESIGN.md §4): each item is a context plus K candidate continuations
//! scored by length-normalized logprob, exactly the decision rule
//! lm-eval-harness applies to PIQA/ARC/BoolQ/HellaSwag/WinoGrande.

pub mod report;
pub mod tasks;

pub use tasks::{task_suites, SuiteSpec, TaskSuite};

use crate::config::ModelConfig;
use crate::corpus::{Batcher, CorpusKind, Generator, Tokenizer};
use crate::model::Params;
use crate::runtime::{tensor_f32, Buffer, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Uploaded parameter set (§Perf): uploaded once, reused across every
/// evaluation batch instead of re-copying all weights per call.
pub struct DeviceParams {
    bufs: Vec<Buffer>,
}

/// Upload a parameter set to the device.
pub fn upload_params(rt: &Runtime, params: &Params) -> Result<DeviceParams> {
    let bufs = params
        .tensors
        .iter()
        .map(|t| rt.upload_f32(t))
        .collect::<Result<Vec<_>>>()?;
    Ok(DeviceParams { bufs })
}

/// Canonical tokenizer: fit once on a fixed wiki+c4 mixture so train,
/// calibration, and eval all share the same vocabulary (and c4's noise
/// tokens get vocabulary slots instead of collapsing to <unk>).
pub fn canonical_tokenizer(cfg: &ModelConfig) -> Tokenizer {
    let mut wiki = Generator::new(CorpusKind::SynthWiki, 42);
    let mut c4 = Generator::new(CorpusKind::SynthC4, 42);
    let mut text = wiki.text(120_000);
    text.push_str(&c4.text(60_000));
    Tokenizer::fit(&text, cfg.vocab)
}

/// Token stream for an eval corpus (disjoint seeds from training).
pub fn eval_ids(cfg: &ModelConfig, kind: CorpusKind, tok: &Tokenizer, seqs: usize) -> Vec<i32> {
    let seed = match kind {
        CorpusKind::SynthWiki => 555,
        CorpusKind::SynthC4 => 556,
    };
    let mut gen = Generator::new(kind, seed);
    let need = (seqs + 2) * cfg.seq + 64;
    tok.encode(&gen.text(need * 2))
}

/// Calibration token stream: seed varies with `calib_seed` so Table 3 can
/// draw disjoint biased samples.
pub fn calib_ids(
    cfg: &ModelConfig,
    tok: &Tokenizer,
    seqs: usize,
    calib_seed: u64,
) -> Vec<i32> {
    let mut gen = Generator::new(CorpusKind::SynthWiki, 9000 + calib_seed);
    let need = (seqs + 2) * cfg.seq + 64;
    tok.encode(&gen.text(need * 2))
}

/// Run `fwd_logits` on one batch, returning logits [B, T, V].
fn forward_logits(
    rt: &Runtime,
    cfg: &ModelConfig,
    dp: &DeviceParams,
    batch: &crate::tensor::TensorI32,
) -> Result<Tensor> {
    let tok_buf = rt.upload_i32(batch)?;
    let mut args: Vec<&Buffer> = dp.bufs.iter().collect();
    args.push(&tok_buf);
    let outs = rt.exec_b(&cfg.name, "fwd_logits", &args)?;
    tensor_f32(&outs[0])
}

/// Per-position logprob of the realized next token.
///
/// logits [B, T, V], tokens [B, T]: returns, for each (b, t < T-1),
/// log softmax(logits[b, t])[tokens[b, t+1]].
fn next_token_logprobs(
    logits: &Tensor,
    tokens: &crate::tensor::TensorI32,
) -> Vec<Vec<f32>> {
    let shape = logits.shape();
    let (b, t, v) = (shape[0], shape[1], shape[2]);
    let data = logits.data();
    let toks = tokens.data();
    let mut out = Vec::with_capacity(b);
    for bi in 0..b {
        let mut row = Vec::with_capacity(t - 1);
        for ti in 0..t - 1 {
            let base = (bi * t + ti) * v;
            let slice = &data[base..base + v];
            let mx = slice.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse = mx + slice.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln();
            let gold = toks[bi * t + ti + 1] as usize;
            row.push(slice[gold] - lse);
        }
        out.push(row);
    }
    out
}

/// Corpus perplexity of `params` over `seqs` sequences of `kind`.
pub fn perplexity(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    tok: &Tokenizer,
    kind: CorpusKind,
    seqs: usize,
) -> Result<f32> {
    let dp = upload_params(rt, params)?;
    perplexity_d(rt, cfg, &dp, tok, kind, seqs)
}

/// Perplexity with pre-uploaded parameters (shared across corpora/suites).
pub fn perplexity_d(
    rt: &Runtime,
    cfg: &ModelConfig,
    dp: &DeviceParams,
    tok: &Tokenizer,
    kind: CorpusKind,
    seqs: usize,
) -> Result<f32> {
    let ids = eval_ids(cfg, kind, tok, seqs);
    let batcher = Batcher::new(cfg.batch, cfg.seq);
    let mut batches = batcher.eval_batches(&ids)?;
    batches.truncate(seqs.div_ceil(cfg.batch));
    if batches.is_empty() {
        bail!("no eval batches for {}", kind.label());
    }
    let mut nll_sum = 0f64;
    let mut count = 0usize;
    for batch in &batches {
        let logits = forward_logits(rt, cfg, dp, batch)?;
        for row in next_token_logprobs(&logits, batch) {
            for lp in row {
                nll_sum -= lp as f64;
                count += 1;
            }
        }
    }
    Ok(((nll_sum / count as f64).exp()) as f32)
}

/// Score a batch of candidate sequences: length-normalized logprob of the
/// last `cont_len` tokens of each row.
fn score_continuations(
    rt: &Runtime,
    cfg: &ModelConfig,
    dp: &DeviceParams,
    rows: &[Vec<i32>],
    cont_len: usize,
) -> Result<Vec<f32>> {
    let t = cfg.seq;
    let b = cfg.batch;
    let mut scores = vec![0.0f32; rows.len()];
    for (chunk_idx, chunk) in rows.chunks(b).enumerate() {
        // Pad the final partial batch by repeating the last row.
        let mut data = Vec::with_capacity(b * t);
        for i in 0..b {
            let row = chunk.get(i).unwrap_or_else(|| chunk.last().unwrap());
            debug_assert_eq!(row.len(), t);
            data.extend_from_slice(row);
        }
        let batch = crate::tensor::TensorI32::from_vec(&[b, t], data)?;
        let logits = forward_logits(rt, cfg, dp, &batch)?;
        let lps = next_token_logprobs(&logits, &batch);
        for (i, row_lp) in lps.iter().enumerate().take(chunk.len()) {
            // Continuation occupies the last cont_len positions; the
            // prediction of token at position p comes from index p-1.
            let lo = t - 1 - cont_len;
            let s: f32 = row_lp[lo..].iter().sum();
            scores[chunk_idx * b + i] = s / cont_len as f32;
        }
    }
    Ok(scores)
}

/// Accuracy of `params` on one synthetic suite.
pub fn suite_accuracy(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    suite: &TaskSuite,
) -> Result<f32> {
    let dp = upload_params(rt, params)?;
    suite_accuracy_d(rt, cfg, &dp, suite)
}

/// Suite accuracy with pre-uploaded parameters.
pub fn suite_accuracy_d(
    rt: &Runtime,
    cfg: &ModelConfig,
    dp: &DeviceParams,
    suite: &TaskSuite,
) -> Result<f32> {
    let mut correct = 0usize;
    for item in &suite.items {
        let scores = score_continuations(rt, cfg, dp, &item.options, suite.spec.cont_len)?;
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if best == item.answer {
            correct += 1;
        }
    }
    Ok(correct as f32 / suite.items.len().max(1) as f32)
}

/// Full metric row: (wikitext2 ppl, c4 ppl, suite accuracies in suite order).
pub struct EvalRow {
    pub ppl_wiki: f32,
    pub ppl_c4: f32,
    pub accs: Vec<(String, f32)>,
}

/// Evaluate everything Table 1 reports for one parameter set.
pub fn eval_all(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    tok: &Tokenizer,
    eval_seqs: usize,
    task_items: usize,
) -> Result<EvalRow> {
    // §Perf: one parameter upload serves every corpus and suite.
    let dp = upload_params(rt, params)?;
    let ppl_wiki = perplexity_d(rt, cfg, &dp, tok, CorpusKind::SynthWiki, eval_seqs)?;
    let ppl_c4 = perplexity_d(rt, cfg, &dp, tok, CorpusKind::SynthC4, eval_seqs)?;
    let mut accs = Vec::new();
    for suite in task_suites(cfg, tok, task_items)? {
        let acc = suite_accuracy_d(rt, cfg, &dp, &suite)?;
        accs.push((suite.spec.name.to_string(), acc));
    }
    Ok(EvalRow {
        ppl_wiki,
        ppl_c4,
        accs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorI32;

    #[test]
    fn logprob_indexing() {
        // V=2, T=3, B=1; uniform logits => logprob = ln(0.5) everywhere.
        let logits = Tensor::from_vec(&[1, 3, 2], vec![0.0; 6]).unwrap();
        let toks = TensorI32::from_vec(&[1, 3], vec![0, 1, 0]).unwrap();
        let lps = next_token_logprobs(&logits, &toks);
        assert_eq!(lps[0].len(), 2);
        for lp in &lps[0] {
            assert!((lp - 0.5f32.ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn logprob_prefers_high_logit() {
        // Position 0 predicts token 1; make logit[1] large.
        let logits = Tensor::from_vec(&[1, 2, 2], vec![0.0, 5.0, 0.0, 0.0]).unwrap();
        let toks = TensorI32::from_vec(&[1, 2], vec![0, 1]).unwrap();
        let lps = next_token_logprobs(&logits, &toks);
        assert!(lps[0][0] > -0.05); // nearly certain
    }

    #[test]
    fn canonical_tokenizer_is_stable() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let a = canonical_tokenizer(&cfg);
        let b = canonical_tokenizer(&cfg);
        assert_eq!(a.vocab_size(), b.vocab_size());
        assert_eq!(a.encode("the cat"), b.encode("the cat"));
        assert!(a.vocab_size() <= cfg.vocab);
    }

    #[test]
    fn eval_and_calib_ids_in_vocab_range() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let tok = canonical_tokenizer(&cfg);
        for ids in [
            eval_ids(&cfg, CorpusKind::SynthWiki, &tok, 4),
            eval_ids(&cfg, CorpusKind::SynthC4, &tok, 4),
            calib_ids(&cfg, &tok, 4, 0),
        ] {
            assert!(ids.len() >= 4 * cfg.seq);
            assert!(ids.iter().all(|&i| (i as usize) < cfg.vocab));
        }
    }

    #[test]
    fn calib_seeds_give_different_streams() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let tok = canonical_tokenizer(&cfg);
        let a = calib_ids(&cfg, &tok, 4, 1);
        let b = calib_ids(&cfg, &tok, 4, 2);
        assert_ne!(a, b);
    }
}
