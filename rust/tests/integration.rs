//! Integration tests over the runtime + artifact entrypoints.
//!
//! These run unconditionally on the native CPU backend (the default when
//! no PJRT artifacts are present), so `cargo test` exercises the full
//! artifact contract on a fresh offline checkout. Under `--features
//! pjrt` with `make artifacts`, the same tests cover the PJRT path.

use faquant::model::Params;
use faquant::quant::{alpha_scale, scaled_fakequant};
use faquant::runtime::{lit_f32, lit_i32, scalar_f32, tensor_f32, Runtime};
use faquant::tensor::{Rng, Tensor, TensorI32};
// Shared tiny-model fixture builders (deduplicated across the crate's
// test suites into src/testutil/fixtures.rs).
use faquant::testutil::fixtures::{pico as cfg, random_tokens as tokens, runtime};

#[test]
fn fwd_logits_shape_and_finite() {
    let rt = runtime();
    let cfg = cfg();
    let params = Params::init(&cfg, 1);
    let mut args: Vec<_> = params.tensors.iter().map(|t| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&tokens(&cfg, 2)).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_logits", &args).unwrap();
    assert_eq!(outs.len(), 1);
    let logits = tensor_f32(&outs[0]).unwrap();
    assert_eq!(logits.shape(), &[cfg.batch, cfg.seq, cfg.vocab]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn arity_mismatch_rejected() {
    let rt = runtime();
    let cfg = cfg();
    let err = match rt.exec(&cfg.name, "fwd_logits", &[]) {
        Ok(_) => panic!("empty-arg exec unexpectedly succeeded"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("args"), "{err}");
}

#[test]
fn unknown_artifact_rejected() {
    let rt = runtime();
    assert!(rt.exec("pico", "nonexistent", &[]).is_err());
    assert!(rt.exec("unknown_cfg", "fwd_logits", &[]).is_err());
}

/// The layer_loss artifact (Pallas scaled_fakequant on-graph) must agree
/// with the rust host implementation of the same math — the bit-parity
/// check that lets the coordinator quantize host-side after searching
/// device-side.
#[test]
fn layer_loss_matches_host_fakequant() {
    let rt = runtime();
    let cfg = cfg();
    let group = rt.manifest.group;
    let rows = rt.manifest.loss_rows;
    let mut rng = Rng::new(3);
    let (n, m) = faquant::model::role_shape(&cfg, "qkv");
    let a = Tensor::randn(&mut rng, &[rows, n], 1.0);
    let w = Tensor::randn(&mut rng, &[n, m], 0.5);
    let stats: Vec<f32> = (0..n).map(|_| rng.uniform() + 0.1).collect();

    for bits in [3u32, 4] {
        for alpha in [0.0f32, 0.5, 1.0] {
            let s = alpha_scale(&stats, alpha);
            let s_t = Tensor::from_vec(&[n], s.clone()).unwrap();
            let outs = rt
                .exec(
                    &cfg.name,
                    &format!("layer_loss_qkv_b{bits}"),
                    &[
                        lit_f32(&a).unwrap(),
                        lit_f32(&w).unwrap(),
                        lit_f32(&s_t).unwrap(),
                    ],
                )
                .unwrap();
            let device_loss = scalar_f32(&outs[0]).unwrap();

            let wq = scaled_fakequant(&w, &s, bits, group).unwrap();
            let host_loss = a.matmul(&wq).unwrap().mse(&a.matmul(&w).unwrap());
            let rel = (device_loss - host_loss).abs() / host_loss.max(1e-9);
            assert!(
                rel < 2e-2,
                "bits={bits} alpha={alpha}: device {device_loss} vs host {host_loss}"
            );
        }
    }
}

/// fwd_capture's stats outputs must equal mean |acts| of its acts outputs
/// (the Pallas absmean kernel vs the activations it summarizes).
#[test]
fn capture_stats_consistent_with_acts() {
    let rt = runtime();
    let cfg = cfg();
    let params = Params::init(&cfg, 4);
    let mut args: Vec<_> = params.tensors.iter().map(|t| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&tokens(&cfg, 5)).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_capture", &args).unwrap();
    assert_eq!(outs.len(), 8);
    for ri in 0..4 {
        let acts = tensor_f32(&outs[ri]).unwrap();
        let stats = tensor_f32(&outs[4 + ri]).unwrap();
        for b in 0..cfg.n_layer {
            let a_b = acts.index0(b);
            let want = a_b.absmean_cols();
            let got = stats.index0(b);
            for (g, w) in got.data().iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "role {ri} block {b}: {g} vs {w}");
            }
        }
    }
}

/// One train_step execution: shapes round-trip, loss finite, step
/// counter increments, parameters actually move.
#[test]
fn train_step_executes_and_updates() {
    let rt = runtime();
    let cfg = cfg();
    let params = Params::init(&cfg, 6);
    let n = params.tensors.len();
    let zeros: Vec<Tensor> = params.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect();
    let mut rng = Rng::new(7);
    let t_train = TensorI32::from_vec(
        &[cfg.batch, cfg.seq + 1],
        (0..cfg.batch * (cfg.seq + 1))
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect(),
    )
    .unwrap();

    let mut args = Vec::new();
    for t in params.tensors.iter().chain(zeros.iter()).chain(zeros.iter()) {
        args.push(lit_f32(t).unwrap());
    }
    args.push(faquant::runtime::lit_scalar(0.0).unwrap());
    args.push(lit_i32(&t_train).unwrap());
    let outs = rt.exec(&cfg.name, "train_step", &args).unwrap();
    assert_eq!(outs.len(), 3 * n + 2);

    let step = scalar_f32(&outs[3 * n]).unwrap();
    let loss = scalar_f32(&outs[3 * n + 1]).unwrap();
    assert_eq!(step, 1.0);
    assert!(loss.is_finite() && loss > 0.0);
    // Random-init loss should be near ln(vocab).
    let uniform = (cfg.vocab as f32).ln();
    assert!((loss - uniform).abs() < 1.5, "loss {loss} vs ln(V) {uniform}");

    let new_w = tensor_f32(&outs[params.index_of("blk0.w_qkv").unwrap()]).unwrap();
    let old_w = params.get("blk0.w_qkv").unwrap();
    assert!(new_w.mse(old_w) > 0.0, "weights did not move");
}

/// fwd_logits_q (int codes + qmatmul kernel) must agree with fwd_logits
/// on host-fakequantized weights — the deployment-path equivalence.
#[test]
fn quantized_forward_matches_fakequant_forward() {
    let rt = runtime();
    let cfg = cfg();
    let group = rt.manifest.group;
    let params = Params::init(&cfg, 8);
    let bits = 4u32;

    // Host-side quantize every block linear with s = 1.
    let mut fq_params = params.clone();
    let mut qm_linears = Vec::new();
    for b in 0..cfg.n_layer {
        for role in faquant::model::ROLES {
            let w = params.role_weight(b, role).unwrap();
            let ones = vec![1.0f32; w.shape()[0]];
            let fq = scaled_fakequant(w, &ones, bits, group).unwrap();
            fq_params
                .set(&faquant::model::role_param(b, role), fq)
                .unwrap();
            let (ints, inv_s) =
                faquant::quant::scaled_quantize_ints(w, &ones, bits, group).unwrap();
            let packed = faquant::quant::packing::pack(&ints.q, bits).unwrap();
            qm_linears.push(faquant::quant::LinearQuant {
                block: b,
                role,
                alpha: 0.0,
                loss: 0.0,
                window_used: 0,
                gamma_used: 1.0,
                scale: ones.clone(),
                ints,
                inv_s,
                packed,
            });
        }
    }
    let qm = faquant::quant::QuantizedModel {
        cfg: cfg.clone(),
        qcfg: faquant::config::QuantConfig::default(),
        fq_params: fq_params.clone(),
        linears: qm_linears,
    };

    let toks = tokens(&cfg, 9);
    // Path A: fwd_logits on fake-quantized weights.
    let mut args: Vec<_> = fq_params.tensors.iter().map(|t| lit_f32(t).unwrap()).collect();
    args.push(lit_i32(&toks).unwrap());
    let a = tensor_f32(&rt.exec(&cfg.name, "fwd_logits", &args).unwrap()[0]).unwrap();

    // Path B: fwd_logits_q on integer codes.
    let mut qargs = faquant::serve::qmodel_literals(&params, &qm).unwrap();
    qargs.push(lit_i32(&toks).unwrap());
    let b = tensor_f32(&rt.exec(&cfg.name, "fwd_logits_q", &qargs).unwrap()[0]).unwrap();

    let mse = a.mse(&b);
    assert!(mse < 1e-4, "deployment path diverges: mse {mse}");
}

#[test]
fn executable_cache_hits() {
    let rt = runtime();
    let cfg = cfg();
    rt.warmup(&cfg.name, &["fwd_logits"]).unwrap();
    let before = rt.stats()["pico/fwd_logits"].compile_secs;
    rt.warmup(&cfg.name, &["fwd_logits"]).unwrap();
    let after = rt.stats()["pico/fwd_logits"].compile_secs;
    assert_eq!(before, after, "second warmup recompiled");
}
