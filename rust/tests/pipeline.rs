//! End-to-end pipeline tests: train -> calibrate -> quantize (all
//! methods) -> evaluate -> serve, on the pico model with tiny budgets.
//!
//! Runs unconditionally on the native CPU backend (no artifacts/ needed);
//! uses a tempdir runs/ so tests never collide with user checkpoints.

use faquant::config::Method;
use faquant::coordinator::Pipeline;
// Shared tiny-model fixture builders (deduplicated across the crate's
// test suites into src/testutil/fixtures.rs).
use faquant::testutil::fixtures::{runtime, tiny_run_config as test_cfg};

#[test]
fn full_pipeline_all_methods() {
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("all");

    // Shared checkpoint + calibration.
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();

    // Calibration invariants.
    assert_eq!(calib.stats.len(), cfg.model.n_layer);
    for b in 0..cfg.model.n_layer {
        for ri in 0..4 {
            let stats = calib.stats_for(b, ri);
            assert!(stats.iter().all(|&v| v >= 0.0 && v.is_finite()));
            let acts = calib.acts_for(b, ri);
            assert_eq!(acts.shape()[0], rt.manifest.loss_rows);
        }
    }

    let mut losses = std::collections::HashMap::new();
    for method in [Method::Rtn, Method::Awq, Method::Faq] {
        let mut c = cfg.clone();
        c.quant.method = method;
        let p = Pipeline::new(&rt, c);
        let (qm, _) = p.quantize(&params, Some(&calib)).unwrap();
        assert_eq!(qm.linears.len(), cfg.model.n_layer * 4);
        // Compression headline: 3-bit should be >4x smaller than fp32.
        let (packed, fp) = qm.compression();
        assert!(fp > packed * 4, "compression too weak: {packed} vs {fp}");
        // Codes fit in the bit width.
        for l in &qm.linears {
            let qmax = (1u32 << qm.qcfg.bits) - 1;
            assert!(l.ints.q.iter().all(|&c| (c as u32) <= qmax));
            assert!(l.loss.is_finite());
        }
        losses.insert(method.name(), qm.mean_loss());
    }
    // Activation-aware search must not be worse than RTN on its own
    // objective (AWQ minimizes exactly this loss; alpha=0 = RTN is in
    // the grid).
    assert!(
        losses["AWQ"] <= losses["RTN"] + 1e-9,
        "AWQ {} > RTN {}",
        losses["AWQ"],
        losses["RTN"]
    );
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn fp_pipeline_skips_quantization() {
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let mut cfg = test_cfg("fp");
    cfg.quant.method = Method::Fp;
    let pipe = Pipeline::new(&rt, cfg.clone());
    let out = pipe.run().unwrap();
    assert!(out.quantized.is_none());
    let row = out.eval.unwrap();
    assert!(row.ppl_wiki.is_finite() && row.ppl_wiki > 1.0);
    assert!(row.ppl_c4.is_finite() && row.ppl_c4 > 1.0);
    assert_eq!(row.accs.len(), 6);
    for (name, acc) in &row.accs {
        assert!((0.0..=1.0).contains(acc), "{name} acc {acc}");
    }
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn quantized_eval_not_catastrophic() {
    // 4-bit FAQ perplexity should stay within 2x of FP (sanity bound:
    // quantization must degrade, not destroy).
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let mut cfg = test_cfg("quality");
    cfg.quant.bits = 4;
    cfg.quant.method = Method::Faq;
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();
    let (fp_row, _) = pipe.evaluate(&params).unwrap();
    let (q_row, _) = pipe.evaluate(&qm.fq_params).unwrap();
    assert!(
        q_row.ppl_wiki < fp_row.ppl_wiki * 2.0,
        "4-bit FAQ ppl {} vs FP {}",
        q_row.ppl_wiki,
        fp_row.ppl_wiki
    );
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn checkpoint_cache_reused() {
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("cache");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (p1, _) = pipe.checkpoint().unwrap();
    let out2 = faquant::train::ensure_checkpoint(
        &rt,
        &cfg.model,
        &cfg.runs_dir,
        cfg.train_steps,
        17,
    )
    .unwrap();
    assert!(out2.cached);
    for (a, b) in p1.tensors.iter().zip(&out2.params.tensors) {
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_roundtrip_quantized() {
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("serve");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    let (tx, rx) = std::sync::mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..6 {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        let tokens: Vec<i32> = (0..cfg.model.seq)
            .map(|k| ((k + i * 7) % cfg.model.vocab) as i32)
            .collect();
        tx.send(faquant::serve::Request {
            tokens,
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    // Malformed requests in the middle of the queue must be rejected
    // alone — not abort the whole serving loop: one with the wrong
    // sequence length, one with the right length but an out-of-range
    // token id (which would blow up the embedding gather mid-batch).
    let (bad_tx, bad_rx) = faquant::serve::oneshot_channel();
    tx.send(faquant::serve::Request {
        tokens: vec![1, 2, 3],
        respond: bad_tx,
    })
    .unwrap();
    let (oob_tx, oob_rx) = faquant::serve::oneshot_channel();
    let mut oob_tokens = vec![1i32; cfg.model.seq];
    oob_tokens[7] = -5;
    tx.send(faquant::serve::Request {
        tokens: oob_tokens,
        respond: oob_tx,
    })
    .unwrap();
    drop(tx);
    let rep = faquant::serve::serve_requests(
        &rt,
        &cfg.model,
        &params,
        &qm,
        rx,
        std::time::Duration::from_millis(1),
        None,
    )
    .unwrap();
    assert_eq!(rep.requests, 6);
    assert_eq!(rep.rejected, 2);
    assert_eq!(rep.reject_counts.wrong_length, 1);
    assert_eq!(rep.reject_counts.bad_token, 1);
    assert!(rep.batches >= 2); // batch=4 -> at least 2 batches for 6 reqs
    for r in responders {
        let resp = r.recv().unwrap();
        let c = resp.completion().expect("valid request served");
        assert_eq!(c.next_logits.len(), cfg.model.vocab);
        assert!(c.next_logits.iter().all(|v| v.is_finite()));
    }
    // The malformed clients hear a structured reason, not a disconnect.
    let bad = bad_rx.recv().unwrap();
    assert_eq!(bad.rejection().unwrap().cause(), "wrong_length");
    let oob = oob_rx.recv().unwrap();
    assert_eq!(oob.rejection().unwrap().cause(), "bad_token");
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_generate_roundtrip() {
    use faquant::engine::{FinishReason, GenConfig};
    use faquant::serve::{GenServeRequest, GenServeResponse};

    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("gen");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    let (tx, rx) = std::sync::mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..5usize {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(GenServeRequest {
            prompt: (0..4 + i).map(|k| ((k * 5 + i) % cfg.model.vocab) as i32).collect(),
            max_new: 3 + i % 3,
            stop_id: None,
            deadline: None,
            cancel: None,
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    // One malformed request mid-queue: rejected with a reason, loop lives.
    let (bad_tx, bad_rx) = faquant::serve::oneshot_channel();
    tx.send(GenServeRequest {
        prompt: vec![],
        max_new: 4,
        stop_id: None,
        deadline: None,
        cancel: None,
        respond: bad_tx,
    })
    .unwrap();
    drop(tx);

    let rep = faquant::serve::serve_generate(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            temperature: 0.7,
            top_k: 12,
            seed: 5,
            slots: 2, // fewer slots than requests: continuous batching
            ..GenConfig::default()
        },
        rx,
        std::time::Duration::from_millis(1),
        None,
    )
    .unwrap();

    for (i, r) in responders.into_iter().enumerate() {
        match r.recv().unwrap() {
            GenServeResponse::Done { tokens, finish, queued_at, done_at } => {
                assert_eq!(finish, FinishReason::MaxTokens);
                assert_eq!(tokens.len(), 3 + i % 3);
                assert!(tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.model.vocab));
                assert!(done_at >= queued_at);
            }
            GenServeResponse::Rejected(r) => panic!("request {i} rejected: {r}"),
        }
    }
    match bad_rx.recv().unwrap() {
        GenServeResponse::Rejected(reason) => assert_eq!(reason.cause(), "empty_prompt"),
        GenServeResponse::Done { .. } => panic!("empty prompt must be rejected"),
    }
    assert_eq!(rep.requests, 6);
    assert_eq!(rep.engine.sequences, 5);
    assert_eq!(rep.engine.rejected, 1);
    assert_eq!(rep.engine.reject_counts.empty_prompt, 1);
    assert!(rep.engine.prefill_tokens > 0 && rep.engine.decode_tokens > 0);
    assert!(rep.engine.mean_slot_occupancy > 0.0);
    assert!(rep.p95_ms >= rep.p50_ms);
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_generate_shared_prefix_reports_hits() {
    use faquant::engine::GenConfig;
    use faquant::serve::{GenServeRequest, GenServeResponse};

    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("genprefix");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    // Three requests with the SAME 12-token prompt (the shared-system-
    // prompt pattern) through a single-slot paged engine: the 2nd and
    // 3rd each skip the cached prefix (11 of 12 prompt tokens — the
    // last prompt token always feeds to seed sampling).
    let shared: Vec<i32> = (0..12)
        .map(|k| ((k * 3 + 1) % cfg.model.vocab) as i32)
        .collect();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut responders = Vec::new();
    for _ in 0..3 {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(GenServeRequest {
            prompt: shared.clone(),
            max_new: 2,
            stop_id: None,
            deadline: None,
            cancel: None,
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    drop(tx);
    let rep = faquant::serve::serve_generate(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            slots: 1,
            block_tokens: 4,
            ..GenConfig::default()
        },
        rx,
        std::time::Duration::from_millis(1),
        None,
    )
    .unwrap();
    let mut streams = Vec::new();
    for r in responders {
        match r.recv().unwrap() {
            GenServeResponse::Done { tokens, .. } => streams.push(tokens),
            GenServeResponse::Rejected(reason) => panic!("rejected: {reason}"),
        }
    }
    // Greedy + identical prompts: identical continuations, with or
    // without the prefix-cache fast path (bit-identity, DESIGN.md §12).
    assert_eq!(streams[0], streams[1]);
    assert_eq!(streams[1], streams[2]);
    assert_eq!(rep.engine.sequences, 3);
    assert_eq!(rep.engine.prefix_hit_tokens, 22, "11 skipped tokens x 2 repeats");
    // Prefill fed: 12 (first) + 1 + 1 (repeats feed only the last token).
    assert_eq!(rep.engine.prefill_tokens, 14);
    assert!(rep.engine.pool_blocks > 0 && rep.engine.peak_blocks_in_use > 0);
    assert!(rep.engine.block_tokens == 4);
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_skips_disconnected_clients_at_dispatch() {
    // A one-shot client that hangs up while queued must not burn a
    // batch slot: its request is dropped at dispatch and counted under
    // `disconnected`, and everyone else is still served.
    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("servedisc");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    let tokens = |i: usize| -> Vec<i32> {
        (0..cfg.model.seq)
            .map(|k| ((k + i * 7) % cfg.model.vocab) as i32)
            .collect()
    };
    let (tx, rx) = std::sync::mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..2 {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(faquant::serve::Request {
            tokens: tokens(i),
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    // A perfectly VALID request whose client already hung up.
    let (dead_tx, dead_rx) = faquant::serve::oneshot_channel();
    tx.send(faquant::serve::Request {
        tokens: tokens(2),
        respond: dead_tx,
    })
    .unwrap();
    drop(dead_rx);
    drop(tx);
    let rep = faquant::serve::serve_requests(
        &rt,
        &cfg.model,
        &params,
        &qm,
        rx,
        std::time::Duration::from_millis(1),
        None,
    )
    .unwrap();
    assert_eq!(rep.requests, 2, "only the live clients are served");
    assert_eq!(rep.reject_counts.disconnected, 1);
    assert_eq!(rep.rejected, 1);
    for r in responders {
        let resp = r.recv().unwrap();
        assert!(resp.completion().is_some(), "live client starved");
    }
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_generate_disconnect_mid_generation_cancels() {
    use faquant::engine::{FinishReason, GenConfig};
    use faquant::serve::{GenServeRequest, GenServeResponse};

    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("gendisc");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    let (tx, rx) = std::sync::mpsc::channel();
    // Victim: a long-budget request whose client hangs up immediately —
    // the loop must convert the dangling receiver into a cancel instead
    // of decoding its whole budget.
    let (victim_tx, victim_rx) = faquant::serve::oneshot_channel();
    tx.send(GenServeRequest {
        prompt: vec![1, 2, 3],
        max_new: 64,
        stop_id: None,
        deadline: None,
        cancel: None,
        respond: victim_tx,
    })
    .unwrap();
    drop(victim_rx);
    let (live_tx, live_rx) = faquant::serve::oneshot_channel();
    tx.send(GenServeRequest {
        prompt: vec![4, 5, 6, 7],
        max_new: 4,
        stop_id: None,
        deadline: None,
        cancel: None,
        respond: live_tx,
    })
    .unwrap();
    drop(tx);
    let rep = faquant::serve::serve_generate(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            slots: 2,
            seed: 17,
            ..GenConfig::default()
        },
        rx,
        std::time::Duration::from_millis(1),
        None,
    )
    .unwrap();
    match live_rx.recv().unwrap() {
        GenServeResponse::Done { tokens, finish, .. } => {
            assert_eq!(finish, FinishReason::MaxTokens);
            assert_eq!(tokens.len(), 4, "survivor must run to completion");
        }
        GenServeResponse::Rejected(r) => panic!("survivor rejected: {r}"),
    }
    assert_eq!(rep.engine.cancelled, 1, "disconnect must become a cancel");
    assert_eq!(rep.engine.sequences, 1);
    assert_eq!(rep.requests, 2);
    assert!(
        rep.engine.decode_tokens < 64,
        "cancelled sequence decoded its whole budget anyway"
    );
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}

#[test]
fn serve_generate_shutdown_drains_queued_requests() {
    use faquant::engine::{CancelToken, GenConfig};
    use faquant::serve::{GenServeRequest, GenServeResponse};

    let rt = runtime();
    std::env::set_var("FAQUANT_QUIET", "1");
    let cfg = test_cfg("gendrain");
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().unwrap();
    let (calib, _) = pipe.calibrate(&params).unwrap();
    let (qm, _) = pipe.quantize(&params, Some(&calib)).unwrap();

    // Shutdown already signalled before the loop starts: every queued
    // request must still hear a structured `Draining` answer — never a
    // silent drop — and the loop must return its report.
    let shutdown = CancelToken::new();
    shutdown.cancel();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..3usize {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(GenServeRequest {
            prompt: vec![1, 2, 3 + i as i32],
            max_new: 4,
            stop_id: None,
            deadline: None,
            cancel: None,
            respond: rtx,
        })
        .unwrap();
        responders.push(rrx);
    }
    drop(tx);
    let rep = faquant::serve::serve_generate(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            slots: 2,
            ..GenConfig::default()
        },
        rx,
        std::time::Duration::from_millis(1),
        Some(shutdown),
    )
    .unwrap();
    for r in responders {
        match r.recv().unwrap() {
            GenServeResponse::Rejected(reason) => assert_eq!(reason.cause(), "draining"),
            GenServeResponse::Done { .. } => panic!("draining engine accepted a request"),
        }
    }
    assert_eq!(rep.engine.reject_counts.draining, 3);
    assert_eq!(rep.requests, 3);
    assert_eq!(rep.engine.sequences, 0);
    std::fs::remove_dir_all(&cfg.runs_dir).ok();
}
