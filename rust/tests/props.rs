//! Property-based tests (mini-proptest) on quantizer invariants,
//! including the paper's **Theorem 1** error-ordering claim.
//!
//! These run host-side only (no PJRT) so they execute in milliseconds and
//! sweep many random cases.

use faquant::calib::{capture, faq_stats, fused_stats, preview_stats};
use faquant::config::{Method, ModelConfig, QuantConfig};
use faquant::engine::{BlockPool, Engine, GenConfig, GenRequest, KvCache, RadixTree};
use faquant::model::Params;
use faquant::quant::{
    alpha_grid, alpha_scale, fakequant, packing, quantize_ints, quantize_model, scaled_fakequant,
};
use faquant::runtime::{lit_f32, lit_i32, Buffer, Runtime, Value};
use faquant::serve::qmodel_literals;
use faquant::store::TensorStore;
use faquant::tensor::{intkern, par, Rng, Tensor, TensorI32};
use faquant::serve::{route_affinity, RouterConfig};
use faquant::testutil::{faults, fixtures, forall, fuzz, router_faults, Pair, TensorGen, UsizeIn};

// ---------------------------------------------------------------- packing

#[test]
fn prop_pack_roundtrip_via_quantints() {
    forall(11, 40, &TensorGen { dims: vec![(32, 128), (8, 64)], multiple_of: 32, std: 1.5 }, |w| {
        for bits in [2u32, 3, 4] {
            let ints = quantize_ints(w, bits, 32).map_err(|e| e.to_string())?;
            let packed = packing::pack(&ints.q, bits).map_err(|e| e.to_string())?;
            let back = packing::unpack(&packed, bits, ints.q.len()).map_err(|e| e.to_string())?;
            if back != ints.q {
                return Err(format!("roundtrip mismatch at bits={bits}"));
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- fakequant

#[test]
fn prop_fakequant_bounded_by_group_range() {
    // Dequantized values stay within the observed [min, max] of their
    // quantization group, up to delta/2 slack from zero-point rounding
    // (z = round(-lo/delta) can shift the representable range by up to
    // half a step — inherent to asymmetric integer zero points).
    forall(12, 30, &TensorGen { dims: vec![(32, 96), (8, 32)], multiple_of: 32, std: 2.0 }, |w| {
        let fq = fakequant(w, 3, 32).map_err(|e| e.to_string())?;
        let (n, m) = (w.shape()[0], w.shape()[1]);
        let qmax = 7.0f32;
        for g in 0..n / 32 {
            for c in 0..m {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for r in 0..32 {
                    let v = w.at2(g * 32 + r, c);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let slack = (hi - lo) / qmax / 2.0 + 1e-4;
                for r in 0..32 {
                    let v = fq.at2(g * 32 + r, c);
                    if v < lo - slack || v > hi + slack {
                        return Err(format!("deq {v} outside [{lo}, {hi}] ± {slack}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alpha_zero_equals_plain_fakequant() {
    // alpha = 0 normalizes to s = 1: AWQ/FAQ degenerate to RTN exactly.
    forall(13, 25, &TensorGen { dims: vec![(32, 64), (8, 32)], multiple_of: 32, std: 1.0 }, |w| {
        let mut rng = Rng::new(w.numel() as u64);
        let stats: Vec<f32> = (0..w.shape()[0]).map(|_| rng.uniform() + 0.1).collect();
        let s = alpha_scale(&stats, 0.0);
        let a = scaled_fakequant(w, &s, 3, 32).map_err(|e| e.to_string())?;
        let b = fakequant(w, 3, 32).map_err(|e| e.to_string())?;
        if a.mse(&b) > 1e-8 {
            return Err(format!("alpha=0 differs from RTN: mse {}", a.mse(&b)));
        }
        Ok(())
    });
}

#[test]
fn prop_more_bits_never_worse() {
    forall(14, 25, &TensorGen { dims: vec![(32, 96), (8, 48)], multiple_of: 32, std: 1.3 }, |w| {
        let errs: Vec<f32> = [2u32, 4, 8]
            .iter()
            .map(|&b| fakequant(w, b, 32).unwrap().mse(w))
            .collect();
        if !(errs[0] >= errs[1] && errs[1] >= errs[2]) {
            return Err(format!("non-monotone errors {errs:?}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------- preview window

#[test]
fn prop_fused_stats_within_envelope() {
    // Fused stats are a convex combination: bounded by min/max of inputs.
    forall(15, 50, &UsizeIn(2, 16), |&n| {
        let mut rng = Rng::new(n as u64 * 7 + 1);
        let cur: Vec<f32> = (0..n).map(|_| rng.uniform() * 5.0).collect();
        let pvw: Vec<f32> = (0..n).map(|_| rng.uniform() * 5.0).collect();
        let gamma = rng.uniform();
        let fused = fused_stats(&cur, &pvw, gamma);
        for i in 0..n {
            let lo = cur[i].min(pvw[i]) - 1e-6;
            let hi = cur[i].max(pvw[i]) + 1e-6;
            if fused[i] < lo || fused[i] > hi {
                return Err(format!("fused[{i}]={} outside [{lo}, {hi}]", fused[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_window_preview_is_mean_of_members() {
    forall(16, 40, &UsizeIn(3, 8), |&layers| {
        let mut rng = Rng::new(layers as u64);
        let stats: Vec<Vec<f32>> = (0..layers)
            .map(|_| (0..4).map(|_| rng.uniform() * 3.0).collect())
            .collect();
        let refs: Vec<&[f32]> = stats.iter().map(|v| v.as_slice()).collect();
        for layer in 0..layers - 1 {
            for window in 1..=layers {
                let Some(p) = preview_stats(&refs, layer, window, false) else {
                    return Err("missing preview for non-last layer".into());
                };
                let hi = (layer + window).min(layers - 1);
                for c in 0..4 {
                    let want: f32 = (layer + 1..=hi).map(|l| stats[l][c]).sum::<f32>()
                        / (hi - layer) as f32;
                    if (p[c] - want).abs() > 1e-5 {
                        return Err(format!("window mean wrong at layer {layer} w={window}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gamma_one_faq_is_awq() {
    forall(17, 40, &UsizeIn(2, 6), |&layers| {
        let mut rng = Rng::new(layers as u64 + 99);
        let stats: Vec<Vec<f32>> = (0..layers)
            .map(|_| (0..6).map(|_| rng.uniform() + 0.05).collect())
            .collect();
        let refs: Vec<&[f32]> = stats.iter().map(|v| v.as_slice()).collect();
        for layer in 0..layers {
            let f = faq_stats(&refs, layer, 3, 1.0, false);
            for (a, b) in f.iter().zip(&stats[layer]) {
                if (a - b).abs() > 1e-6 {
                    return Err("gamma=1 FAQ != AWQ stats".into());
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- parallel compute core

/// Naive (i, l, j) triple-loop oracles, written here independently of
/// the library kernels. No zero-skip branch: 0 * NaN / 0 * Inf must
/// reach the accumulator exactly as in the blocked kernels.
fn naive_matmul(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (r, k) = (a.shape()[0], a.shape()[1]);
    let c = b.shape()[1];
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for l in 0..k {
            let av = a.at2(i, l);
            for j in 0..c {
                out[i * c + j] += av * b.at2(l, j);
            }
        }
    }
    out
}

fn naive_matmul_tn(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (r, n) = (a.shape()[0], a.shape()[1]);
    let m = b.shape()[1];
    let mut out = vec![0.0f32; n * m];
    for row in 0..r {
        for i in 0..n {
            let av = a.at2(row, i);
            for j in 0..m {
                out[i * m + j] += av * b.at2(row, j);
            }
        }
    }
    out
}

fn naive_matmul_nt(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (r, k) = (a.shape()[0], a.shape()[1]);
    let m = b.shape()[0];
    let mut out = vec![0.0f32; r * m];
    for i in 0..r {
        for j in 0..m {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a.at2(i, l) * b.at2(j, l);
            }
            out[i * m + j] = acc;
        }
    }
    out
}

/// Sprinkle NaN/Inf/-Inf/0 into a tensor so the oracle comparison also
/// pins down special-value propagation (the old kernel's `a == 0.0`
/// skip branch swallowed NaN — a silent semantics change).
fn inject_specials(t: &mut Tensor, rng: &mut Rng) {
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0];
    let n = t.numel();
    for _ in 0..4 {
        let i = rng.below(n);
        let s = specials[rng.below(specials.len())];
        t.data_mut()[i] = s;
    }
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}: element {i} differs ({g} vs {w})"
        );
    }
}

#[test]
fn prop_blocked_matmul_kernels_match_naive_reference() {
    // Random shapes straddling the MR/KC tile boundaries, with NaN/Inf
    // injected: the blocked, parallel kernels must be bitwise equal to
    // the naive triple loops (fixed ascending-k accumulation order).
    forall(22, 25, &UsizeIn(1, 1_000_000), |&seed| {
        let mut rng = Rng::new(seed as u64 * 331 + 17);
        let r = 1 + rng.below(18);
        let k = 1 + rng.below(300);
        let c = 1 + rng.below(40);
        let m = 1 + rng.below(24);
        let mut a = Tensor::randn(&mut rng, &[r, k], 1.0);
        let mut b = Tensor::randn(&mut rng, &[k, c], 1.0);
        inject_specials(&mut a, &mut rng);
        inject_specials(&mut b, &mut rng);
        let got = a.matmul(&b).map_err(|e| e.to_string())?;
        assert_bits_eq(got.data(), &naive_matmul(&a, &b), "matmul");

        // tn: [r, k]^T @ [r, m]; nt: [r, k] @ [m, k]^T.
        let mut b_tn = Tensor::randn(&mut rng, &[r, m], 1.0);
        inject_specials(&mut b_tn, &mut rng);
        let got = a.matmul_tn(&b_tn).map_err(|e| e.to_string())?;
        assert_bits_eq(got.data(), &naive_matmul_tn(&a, &b_tn), "matmul_tn");

        let mut b_nt = Tensor::randn(&mut rng, &[m, k], 1.0);
        inject_specials(&mut b_nt, &mut rng);
        let got = a.matmul_nt(&b_nt).map_err(|e| e.to_string())?;
        assert_bits_eq(got.data(), &naive_matmul_nt(&a, &b_nt), "matmul_nt");
        Ok(())
    });
}

/// Everything the quantizer emits, flattened to bit patterns.
fn quantize_fingerprint(rt: &Runtime, cfg: &ModelConfig, params: &Params) -> Vec<u32> {
    let toks = fixtures::random_tokens(cfg, 4242);
    let calib = capture(rt, cfg, params, std::slice::from_ref(&toks), 1).unwrap();
    let qcfg = QuantConfig::with_method(Method::Faq);
    let qm = quantize_model(rt, &qcfg, params, Some(&calib)).unwrap();

    let mut fp: Vec<u32> = Vec::new();
    for l in &qm.linears {
        fp.push(l.alpha.to_bits());
        fp.push(l.loss.to_bits());
        fp.push(l.window_used as u32);
        fp.push(l.gamma_used.to_bits());
        fp.extend(l.scale.iter().map(|s| s.to_bits()));
        fp.extend(l.inv_s.iter().map(|s| s.to_bits()));
        fp.extend(l.packed.iter().copied());
    }
    for t in &qm.fq_params.tensors {
        fp.extend(t.data().iter().map(|v| v.to_bits()));
    }
    // Quantized forward logits on the same tokens.
    let mut args: Vec<Value> = qm
        .fq_params
        .tensors
        .iter()
        .map(|t| lit_f32(t).unwrap())
        .collect();
    args.push(lit_i32(&toks).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_logits", &args).unwrap();
    fp.extend(
        outs[0]
            .as_f32()
            .unwrap()
            .data()
            .iter()
            .map(|v| v.to_bits()),
    );
    fp
}

#[test]
fn quantize_and_forward_bit_identical_across_thread_counts() {
    // The ISSUE-2 determinism contract: FAQUANT_THREADS ∈ {1, 2, 8}
    // must produce bit-identical chosen alphas, losses, scales, packed
    // ints, fake-quant weights, and forward logits, so Tables 1-3 never
    // depend on the runner's core count.
    let rt = Runtime::native();
    let cfg = ModelConfig::preset("pico").unwrap();
    let params = Params::init(&cfg, 31);
    let baseline = {
        par::set_threads(1);
        quantize_fingerprint(&rt, &cfg, &params)
    };
    for &t in &[2usize, 8] {
        par::set_threads(t);
        let fp = quantize_fingerprint(&rt, &cfg, &params);
        par::set_threads(0);
        assert_eq!(
            fp.len(),
            baseline.len(),
            "fingerprint length differs at {t} threads"
        );
        let diffs = fp
            .iter()
            .zip(&baseline)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(diffs, 0, "{diffs} words differ between 1 and {t} threads");
    }
    par::set_threads(0);
}

// ------------------------------------------------- KV-cached decode engine

/// Feed `toks` through `decode_step_q` one token per step, slot `s`
/// starting at global step `offsets[s]` (staggered admission exercises
/// the continuous-batching path: every step mixes slots at different
/// positions, some inactive). `prepared` selects the dequantize-once
/// packed-panel weight bundle (DESIGN.md §11) instead of the per-step
/// dequantizing seed path. Returns the per-position logits [B, T, V].
fn decode_all_positions(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &faquant::quant::QuantizedModel,
    toks: &TensorI32,
    offsets: &[usize],
    prepared: bool,
) -> Tensor {
    let (b, t) = (toks.shape()[0], toks.shape()[1]);
    let v = cfg.vocab;
    let lits = qmodel_literals(params, qm).unwrap();
    let bufs: Vec<Buffer> = if prepared {
        (*rt.prepare_qweights(&cfg.name, &lits).unwrap()).clone()
    } else {
        lits.iter().map(|l| rt.upload_literal(l).unwrap()).collect()
    };
    let mut cache = KvCache::new(cfg.n_layer, b, t, cfg.d_model);
    let mut out = vec![0.0f32; b * t * v];
    let max_step = offsets.iter().max().unwrap() + t;
    for step in 0..max_step {
        let mut pos = vec![-1i32; b];
        let mut tk = vec![0i32; b];
        let mut active = Vec::new();
        for s in 0..b {
            if step < offsets[s] {
                continue;
            }
            let c = step - offsets[s];
            if c < t {
                pos[s] = c as i32;
                tk[s] = toks.data()[s * t + c];
                active.push((s, c));
            }
        }
        if active.is_empty() {
            continue;
        }
        let (kt, vt) = cache.take().unwrap();
        let k_buf = Buffer::Host(Value::F32(kt));
        let v_buf = Buffer::Host(Value::F32(vt));
        let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], pos).unwrap()));
        let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], tk).unwrap()));
        let outs = {
            let mut args: Vec<&Buffer> = bufs.iter().collect();
            args.extend([&k_buf, &v_buf, &pos_buf, &tok_buf]);
            rt.exec_b(&cfg.name, "decode_step_q", &args).unwrap()
        };
        match (k_buf, v_buf) {
            (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(vv))) => {
                cache.put_back(k, vv).unwrap()
            }
            _ => unreachable!("slabs stay host-resident"),
        }
        let logits = outs[0].as_f32().unwrap();
        let k_new = outs[1].as_f32().unwrap();
        let v_new = outs[2].as_f32().unwrap();
        for &(s, c) in &active {
            cache.append(s, k_new, v_new).unwrap();
            out[(s * t + c) * v..(s * t + c + 1) * v]
                .copy_from_slice(&logits.data()[s * v..(s + 1) * v]);
        }
    }
    Tensor::from_vec(&[b, t, v], out).unwrap()
}

/// Paged twin of [`decode_all_positions`]: the same staggered schedule
/// through `decode_step_paged_q`, with per-slot block tables growing one
/// pool page at a time (always the prepared weight bundle).
fn decode_all_positions_paged(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &faquant::quant::QuantizedModel,
    toks: &TensorI32,
    offsets: &[usize],
    block_tokens: usize,
) -> Tensor {
    let (b, t) = (toks.shape()[0], toks.shape()[1]);
    let v = cfg.vocab;
    let lits = qmodel_literals(params, qm).unwrap();
    let bufs: Vec<Buffer> = (*rt.prepare_qweights(&cfg.name, &lits).unwrap()).clone();
    let max_blocks = t.div_ceil(block_tokens);
    let mut pool = BlockPool::new(cfg.n_layer, b * max_blocks, block_tokens, cfg.d_model);
    let mut tables: Vec<Vec<u32>> = (0..b).map(|_| Vec::new()).collect();
    let mut out = vec![0.0f32; b * t * v];
    let max_step = offsets.iter().max().unwrap() + t;
    for step in 0..max_step {
        let mut pos = vec![-1i32; b];
        let mut tk = vec![0i32; b];
        let mut active = Vec::new();
        for s in 0..b {
            if step < offsets[s] {
                continue;
            }
            let c = step - offsets[s];
            if c < t {
                pos[s] = c as i32;
                tk[s] = toks.data()[s * t + c];
                active.push((s, c));
            }
        }
        if active.is_empty() {
            continue;
        }
        let mut tb = vec![-1i32; b * max_blocks];
        for (s, table) in tables.iter().enumerate() {
            for (i, &blk) in table.iter().enumerate() {
                tb[s * max_blocks + i] = blk as i32;
            }
        }
        let (kt, vt) = pool.take().unwrap();
        let k_buf = Buffer::Host(Value::F32(kt));
        let v_buf = Buffer::Host(Value::F32(vt));
        let tb_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b, max_blocks], tb).unwrap()));
        let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], pos).unwrap()));
        let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], tk).unwrap()));
        let outs = {
            let mut args: Vec<&Buffer> = bufs.iter().collect();
            args.extend([&k_buf, &v_buf, &tb_buf, &pos_buf, &tok_buf]);
            rt.exec_b(&cfg.name, "decode_step_paged_q", &args).unwrap()
        };
        match (k_buf, v_buf) {
            (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(vv))) => {
                pool.put_back(k, vv).unwrap()
            }
            _ => unreachable!("pool stays host-resident"),
        }
        let logits = outs[0].as_f32().unwrap();
        let k_new = outs[1].as_f32().unwrap();
        let v_new = outs[2].as_f32().unwrap();
        for &(s, c) in &active {
            if c / block_tokens == tables[s].len() {
                tables[s].push(pool.alloc().unwrap());
            }
            pool.write_row(tables[s][c / block_tokens], c % block_tokens, s, k_new, v_new)
                .unwrap();
            out[(s * t + c) * v..(s * t + c + 1) * v]
                .copy_from_slice(&logits.data()[s * v..(s + 1) * v]);
        }
    }
    Tensor::from_vec(&[b, t, v], out).unwrap()
}

#[test]
fn paged_decode_gather_matches_full_forward_bitwise() {
    // DESIGN §12: the block-table gather reads bitwise-identical rows in
    // the identical ascending order, so paged decode logits equal the
    // full-sequence quantized forward at every position — for page sizes
    // that divide T and ones that do not, at 1/2/8 threads, under
    // staggered continuous-batching admission.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 77);
    let (b, t) = (4usize, 16usize);
    let mut rng = Rng::new(123);
    let toks = TensorI32::from_vec(
        &[b, t],
        (0..b * t).map(|_| rng.below(cfg.vocab) as i32).collect(),
    )
    .unwrap();

    par::set_threads(1);
    let mut args: Vec<Value> = qmodel_literals(&params, &qm).unwrap();
    args.push(lit_i32(&toks).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_logits_q", &args).unwrap();
    let full = outs[0].as_f32().unwrap().clone();

    for &bt in &[3usize, 4, 16] {
        for &threads in &[1usize, 2, 8] {
            par::set_threads(threads);
            let dec =
                decode_all_positions_paged(&rt, &cfg, &params, &qm, &toks, &[0, 3, 5, 11], bt);
            let ctx = format!("paged decode (bt={bt}) vs full at {threads} threads");
            assert_bits_eq(dec.data(), full.data(), &ctx);
        }
    }
    par::set_threads(0);
}

#[test]
fn decode_with_kv_cache_matches_full_forward_bitwise() {
    // THE engine contract: KV-cached decode logits are bitwise equal to
    // the full-sequence quantized forward at every position — at 1/2/8
    // threads and under staggered continuous-batching admission.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 77);
    let (b, t) = (4usize, 16usize);
    let mut rng = Rng::new(123);
    let toks = TensorI32::from_vec(
        &[b, t],
        (0..b * t).map(|_| rng.below(cfg.vocab) as i32).collect(),
    )
    .unwrap();

    par::set_threads(1);
    let mut args: Vec<Value> = qmodel_literals(&params, &qm).unwrap();
    args.push(lit_i32(&toks).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_logits_q", &args).unwrap();
    let full = outs[0].as_f32().unwrap().clone();
    assert_eq!(full.shape(), &[b, t, cfg.vocab]);

    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let dec = decode_all_positions(&rt, &cfg, &params, &qm, &toks, &[0, 3, 5, 11], false);
        let ctx = format!("decode vs full at {threads} threads");
        assert_bits_eq(dec.data(), full.data(), &ctx);
    }
    par::set_threads(0);
}

#[test]
fn prepared_paths_bit_identical_to_seed_qlin() {
    // The DESIGN §11 contract: the prepared (dequantize-once packed
    // panels + scratch arenas) path produces logits bitwise equal to the
    // seed per-call-dequant path — for fwd_logits_q and for
    // decode_step_q under staggered continuous-batching admission, at
    // 1/2/8 threads.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 77);
    let (b, t) = (4usize, 16usize);
    let mut rng = Rng::new(321);
    let toks = TensorI32::from_vec(
        &[b, t],
        (0..b * t).map(|_| rng.below(cfg.vocab) as i32).collect(),
    )
    .unwrap();

    // Seed reference: host-value fwd_logits_q (per-call dequant).
    par::set_threads(1);
    let lits = qmodel_literals(&params, &qm).unwrap();
    let mut args: Vec<Value> = lits.clone();
    args.push(lit_i32(&toks).unwrap());
    let outs = rt.exec(&cfg.name, "fwd_logits_q", &args).unwrap();
    let full = outs[0].as_f32().unwrap().clone();

    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        // Prepared full-sequence scoring.
        let bufs = rt.prepare_qweights(&cfg.name, &lits).unwrap();
        let tok_buf = rt.upload_i32(&toks).unwrap();
        let mut bargs: Vec<&Buffer> = bufs.iter().collect();
        bargs.push(&tok_buf);
        let outs = rt.exec_b(&cfg.name, "fwd_logits_q", &bargs).unwrap();
        let ctx = format!("prepared fwd_logits_q vs seed at {threads} threads");
        assert_bits_eq(outs[0].as_f32().unwrap().data(), full.data(), &ctx);

        // Prepared KV-cached decode, staggered admission.
        let dec = decode_all_positions(&rt, &cfg, &params, &qm, &toks, &[0, 3, 5, 11], true);
        let ctx = format!("prepared decode vs seed full at {threads} threads");
        assert_bits_eq(dec.data(), full.data(), &ctx);
    }
    par::set_threads(0);
    // All prepared calls above shared ONE cached bundle.
    assert_eq!(rt.prepared_qweights(), 1);
}

#[test]
fn generation_deterministic_across_threads_and_slot_counts() {
    // Seeded-sampler determinism: the same (seed, request id) pair must
    // produce the same tokens regardless of thread count or how many
    // slots the engine batches over (different slot counts change every
    // step's batch composition).
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 31);
    let reqs = || -> Vec<GenRequest> {
        (0..5)
            .map(|i| GenRequest {
                id: i,
                prompt: (0..3 + i).map(|k| ((k * 7 + i) % cfg.vocab) as i32).collect(),
                max_new: 6,
                stop_id: None,
                ..Default::default()
            })
            .collect()
    };
    let run = |slots: usize, threads: usize| -> Vec<Vec<i32>> {
        par::set_threads(threads);
        let mut eng = Engine::new(
            &rt,
            &cfg,
            &params,
            &qm,
            GenConfig {
                temperature: 0.9,
                top_k: 8,
                seed: 2024,
                slots,
                ..GenConfig::default()
            },
        )
        .unwrap();
        let (outs, _) = eng.generate(reqs()).unwrap();
        par::set_threads(0);
        outs.into_iter().map(|o| o.tokens).collect()
    };
    let base = run(4, 1);
    assert_eq!(base.len(), 5);
    assert!(base.iter().all(|tks| tks.len() == 6));
    assert_eq!(base, run(2, 8), "slot/thread count changed sampled tokens");
    assert_eq!(base, run(3, 2), "slot/thread count changed sampled tokens");
    assert_eq!(base, run(4, 1), "same run not reproducible");
}

// ------------------------------------------------------------ tensor store

#[test]
fn prop_store_roundtrips_and_rejects_any_truncation() {
    forall(33, 15, &UsizeIn(1, 1_000_000), |&seed| {
        let mut rng = Rng::new(seed as u64 * 77 + 3);
        let mut s = TensorStore::new();
        for i in 0..(1 + rng.below(3)) {
            let r = 1 + rng.below(6);
            let c = 1 + rng.below(6);
            s.insert(&format!("t{i}"), Tensor::randn(&mut rng, &[r, c], 1.0));
        }
        let fname = format!("faquant_prop_store_{}_{seed}.fqt", std::process::id());
        let p = std::env::temp_dir().join(fname);
        s.save(&p).map_err(|e| e.to_string())?;
        let full = std::fs::read(&p).map_err(|e| e.to_string())?;
        // The intact file roundtrips bit-exactly.
        let back = TensorStore::load(&p).map_err(|e| e.to_string())?;
        if back.len() != s.len() {
            return Err("entry count differs after roundtrip".into());
        }
        for name in s.names() {
            let a = s.get(name).map_err(|e| e.to_string())?;
            let b = back.get(name).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("tensor '{name}' differs after roundtrip"));
            }
        }
        // EVERY strict prefix must fail with an error (clear truncation
        // diagnostics, no panic, no OOM), since the format has no
        // trailing padding.
        for _ in 0..4 {
            let cut = 4 + rng.below(full.len() - 4);
            std::fs::write(&p, &full[..cut]).map_err(|e| e.to_string())?;
            if TensorStore::load(&p).is_ok() {
                return Err(format!("truncated file (cut at {cut}) loaded"));
            }
        }
        std::fs::remove_file(&p).ok();
        Ok(())
    });
}

// ----------------------------------- paged KV cache: differential fuzzing

// THE ISSUE-5 contract: the block-paged engine (prefix sharing, copy-on-
// write, LRU eviction, block-granular admission) produces bitwise the
// dense seed engine's token streams on seeded random workloads — shared-
// prefix families, mid-stream divergence, random admission times, stop
// conditions, deliberate rejects, eviction pressure — at 1/2/8 threads.
// Three pinned seeds run here and in the `fuzz-smoke` CI job (which adds
// a fresh seed derived from the CI run id, logged for reproduction).

#[test]
fn fuzz_differential_pinned_seed_a() {
    fuzz::differential_fuzz_case(0xFAC7_0001).unwrap();
}

#[test]
fn fuzz_differential_pinned_seed_b() {
    fuzz::differential_fuzz_case(0xFAC7_0002).unwrap();
}

#[test]
fn fuzz_differential_pinned_seed_c() {
    fuzz::differential_fuzz_case(0xFAC7_0003).unwrap();
}

/// CI's fresh-seed entry: `FAQUANT_FUZZ_SEED=<u64>` (the fuzz-smoke job
/// derives it from the run id and echoes it, so any failure reproduces
/// locally with the same variable). A no-op when the variable is unset.
#[test]
fn fuzz_differential_env_seed() {
    let Ok(raw) = std::env::var("FAQUANT_FUZZ_SEED") else {
        println!("FAQUANT_FUZZ_SEED unset; skipping the fresh-seed differential run");
        return;
    };
    let seed: u64 = raw
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("FAQUANT_FUZZ_SEED must be a u64, got '{raw}'"));
    println!("running fresh-seed differential fuzz: FAQUANT_FUZZ_SEED={seed}");
    fuzz::differential_fuzz_case(seed).unwrap();
}

// ------------------------------------------ int8×int4 compute path (W4A8)

// THE ISSUE-10 contract (DESIGN.md §17): the integer compute path is
// pinned twice over. WITHIN the int path every step is exact integer
// arithmetic plus a deterministic f32 fixup, so results are bitwise
// identical across thread counts AND across kernel lanes (scalar vs
// SIMD) — a forced-dispatch bit-equality test, not a tolerance. AGAINST
// the f32 path the int path runs a different activation quantizer, so
// the contract there is a *derived* tolerance: per output element, the
// half-step bound computed from the quantizer's own constants
// (`intkern::row_error_bound`) — no hand-tuned epsilon anywhere.

#[test]
fn int_linear_within_derived_bound_of_f32_for_every_linear() {
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 91);
    let lits = qmodel_literals(&params, &qm).unwrap();
    let bufs = rt.prepare_qweights(&cfg.name, &lits).unwrap();
    let Buffer::PreparedQ(pm) = &bufs[0] else {
        panic!("native prepare_qweights must return a prepared bundle");
    };
    assert_eq!(pm.int_reason(), None, "pico RTN bundle must pack int panels");
    let mut rng = Rng::new(4242);
    let rows = 5usize;
    let mut max_err = 0.0f64;
    for b in 0..cfg.n_layer {
        // ROLES order (qkv, o, up, down): input widths from the config.
        let widths = [cfg.d_model, cfg.d_model, cfg.d_model, cfg.d_ff];
        for (role, &k) in widths.iter().enumerate() {
            let x = Tensor::randn(&mut rng, &[rows, k], 1.0);
            let (xs, wdq, yf, yi) = pm.qlin_diff(b, role, &x).unwrap();
            let c = wdq.shape()[1];
            let mut xq = vec![0i8; k];
            for r in 0..rows {
                let a_scale = intkern::quantize_row_i8(xs.row(r), &mut xq);
                for j in 0..c {
                    let col_l1: f64 = (0..k).map(|l| (wdq.at2(l, j) as f64).abs()).sum();
                    let moment: f64 = (0..k)
                        .map(|l| (wdq.at2(l, j) as f64 * xs.at2(r, l) as f64).abs())
                        .sum();
                    let bound = intkern::row_error_bound(a_scale, col_l1, moment, k);
                    let err = (yi.at2(r, j) as f64 - yf.at2(r, j) as f64).abs();
                    assert!(
                        err <= bound,
                        "block {b} role {role} ({r}, {j}): err {err} > derived bound {bound}"
                    );
                    max_err = max_err.max(err);
                }
            }
        }
    }
    // The tolerance is doing real work: the two paths genuinely differ.
    assert!(max_err > 0.0, "int and f32 paths never differed — vacuous bound");
}

#[test]
fn int_fwd_logits_bitwise_stable_across_threads_and_lanes() {
    // Forcing the kernel lane mid-run is safe for concurrently running
    // tests: the lanes are bitwise interchangeable (pinned by intkern's
    // in-module tests), so a dispatch flip never changes any output.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 58);
    let (b, t) = (4usize, 16usize);
    let mut rng = Rng::new(777);
    let toks = TensorI32::from_vec(
        &[b, t],
        (0..b * t).map(|_| rng.below(cfg.vocab) as i32).collect(),
    )
    .unwrap();
    let lits = qmodel_literals(&params, &qm).unwrap();
    let bufs = rt.prepare_qweights(&cfg.name, &lits).unwrap();
    let tok_buf = rt.upload_i32(&toks).unwrap();
    let mut bargs: Vec<&Buffer> = bufs.iter().collect();
    bargs.push(&tok_buf);

    let run = |kernel: intkern::IntKernel, threads: usize| -> Tensor {
        intkern::set_int_kernel(kernel);
        par::set_threads(threads);
        let outs = rt.exec_b(&cfg.name, "fwd_logits_qi", &bargs).unwrap();
        par::set_threads(0);
        intkern::set_int_kernel(intkern::IntKernel::Auto);
        outs[0].as_f32().unwrap().clone()
    };
    let base = run(intkern::IntKernel::Scalar, 1);
    for &threads in &[2usize, 8] {
        let got = run(intkern::IntKernel::Scalar, threads);
        let ctx = format!("int logits, scalar lane at {threads} threads");
        assert_bits_eq(got.data(), base.data(), &ctx);
    }
    if intkern::simd_available() {
        for &threads in &[1usize, 2, 8] {
            let got = run(intkern::IntKernel::Simd, threads);
            let ctx = format!("int logits, simd lane at {threads} threads");
            assert_bits_eq(got.data(), base.data(), &ctx);
        }
    } else {
        println!("no SIMD int lane on this host; scalar-only bit-stability checked");
    }
}

// Pinned int-compute seeds: `require_exact` demands the int greedy
// streams match the f32 prepared oracle token for token. That is NOT
// true of arbitrary seeds (the int path is a different quantizer; a
// near-tied argmax can legitimately flip) — these three were screened
// offline for comfortable top-2 margins on every greedy position of
// both paths, so they pin exact agreement stably. Fresh CI seeds go
// through `int_compute_env_seed` below, which checks every bitwise
// contract but not exactness-vs-f32.

#[test]
fn int_compute_pinned_seed_a() {
    fuzz::int_compute_fuzz_case(0xFAC7_10D4, true).unwrap();
}

#[test]
fn int_compute_pinned_seed_b() {
    fuzz::int_compute_fuzz_case(0xFAC7_11A6, true).unwrap();
}

#[test]
fn int_compute_pinned_seed_c() {
    fuzz::int_compute_fuzz_case(0xFAC7_2102, true).unwrap();
}

/// CI's fresh-seed entry: `FAQUANT_INT_SEED=<u64>` (the int-smoke job
/// derives it from the run id and echoes it, so any failure reproduces
/// locally with the same variable). A no-op when the variable is unset.
#[test]
fn int_compute_env_seed() {
    let Ok(raw) = std::env::var("FAQUANT_INT_SEED") else {
        println!("FAQUANT_INT_SEED unset; skipping the fresh-seed int-compute run");
        return;
    };
    let seed: u64 = raw
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("FAQUANT_INT_SEED must be a u64, got '{raw}'"));
    println!("running fresh-seed int-compute fuzz: FAQUANT_INT_SEED={seed}");
    fuzz::int_compute_fuzz_case(seed, false).unwrap();
}

// --------------------------------- request lifecycle: fault injection

// THE ISSUE-7 contract: under a seeded fault plan (transient and
// poisoned-request step failures, admission stalls, client cancels,
// deadline storms, a graceful drain), the engine keeps serving — paged
// invariants hold after every step, the pool leaks zero blocks after the
// drain, every surviving request's tokens are bitwise identical to the
// fault-free run of the same seed, and the whole faulted run is itself
// bitwise reproducible at 1/2/8 threads. Three pinned seeds run here and
// in the `fault-smoke` CI job (which adds a fresh seed derived from the
// CI run id, logged for reproduction).

#[test]
fn fault_injection_pinned_seed_a() {
    faults::fault_injection_case(0xFA17_0001).unwrap();
}

#[test]
fn fault_injection_pinned_seed_b() {
    faults::fault_injection_case(0xFA17_0002).unwrap();
}

#[test]
fn fault_injection_pinned_seed_c() {
    faults::fault_injection_case(0xFA17_0003).unwrap();
}

/// CI's fresh-seed entry: `FAQUANT_FAULT_SEED=<u64>` (the fault-smoke
/// job derives it from the run id and echoes it, so any failure
/// reproduces locally with the same variable). A no-op when unset.
#[test]
fn fault_injection_env_seed() {
    let Ok(raw) = std::env::var("FAQUANT_FAULT_SEED") else {
        println!("FAQUANT_FAULT_SEED unset; skipping the fresh-seed fault-injection run");
        return;
    };
    let seed: u64 = raw
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("FAQUANT_FAULT_SEED must be a u64, got '{raw}'"));
    println!("running fresh-seed fault injection: FAQUANT_FAULT_SEED={seed}");
    faults::fault_injection_case(seed).unwrap();
}

// ----------------------------------- sharded router: failover + routing

// THE ISSUE-9 contract: worker placement and worker failure are
// invisible in the streams. A seeded worker-crash/stall/restart plan
// driven through the sharded router must leave every request's final
// token stream bitwise identical to the fault-free single-engine run —
// untargeted and re-routed requests alike, at 1/2/8 compute threads —
// with zero orphaned queue entries and zero leaked KV blocks after the
// drain (`testutil::router_faults::router_failover_case`). Three pinned
// seeds run here and in the `router-smoke` CI job (which adds a fresh
// seed from the run id, logged for reproduction).

#[test]
fn router_failover_pinned_seed_a() {
    router_faults::router_failover_case(0x40F7_0001, 2).unwrap();
}

#[test]
fn router_failover_pinned_seed_b() {
    router_faults::router_failover_case(0x40F7_0002, 3).unwrap();
}

#[test]
fn router_failover_pinned_seed_c() {
    router_faults::router_failover_case(0x40F7_0003, 4).unwrap();
}

/// CI's fresh-seed entry: `FAQUANT_ROUTER_SEED=<u64>` (the router-smoke
/// job derives it from the run id and echoes it, so any failure
/// reproduces locally with the same variable). A no-op when unset.
#[test]
fn router_failover_env_seed() {
    let Ok(raw) = std::env::var("FAQUANT_ROUTER_SEED") else {
        println!("FAQUANT_ROUTER_SEED unset; skipping the fresh-seed router failover run");
        return;
    };
    let seed: u64 = raw
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("FAQUANT_ROUTER_SEED must be a u64, got '{raw}'"));
    println!("running fresh-seed router failover: FAQUANT_ROUTER_SEED={seed}");
    router_faults::router_failover_case(seed, 3).unwrap();
}

/// Independent re-implementation of the affinity hash (bytes collected
/// first, direct slicing) for the oracle property below.
fn affinity_oracle(prompt: &[i32], block_tokens: usize, workers: usize) -> Option<usize> {
    if workers == 0 || block_tokens == 0 {
        return None;
    }
    let hashed = (prompt.len() / block_tokens).min(4) * block_tokens;
    if hashed == 0 {
        return None;
    }
    let mut bytes = Vec::with_capacity(hashed * 4);
    for &t in &prompt[..hashed] {
        bytes.extend_from_slice(&(t as u32).to_le_bytes());
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
    }
    Some((h % workers as u64) as usize)
}

// Affinity routing is a pure function of (leading prompt blocks, worker
// set): matches a naive oracle, stays in range, never declines when a
// complete block exists, and ignores every token beyond the hashed
// chain.
#[test]
fn affinity_routing_matches_naive_oracle_and_is_pure() {
    forall(
        0x40F7_0B5E,
        300,
        &Pair(
            Pair(UsizeIn(0, 64), UsizeIn(1, 8)),
            Pair(UsizeIn(1, 9), UsizeIn(0, 1 << 30)),
        ),
        |&((len, workers), (block_tokens, tseed))| {
            let mut rng = Rng::new(tseed as u64);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(997) as i32).collect();
            let got = route_affinity(&prompt, block_tokens, workers);
            let want = affinity_oracle(&prompt, block_tokens, workers);
            if got != want {
                return Err(format!("oracle disagrees: {got:?} vs {want:?}"));
            }
            if got != route_affinity(&prompt, block_tokens, workers) {
                return Err("routing is not deterministic".to_string());
            }
            match got {
                Some(w) => {
                    if w >= workers {
                        return Err(format!("worker {w} out of range ({workers} workers)"));
                    }
                    // Suffix independence: once the hashed chain is
                    // saturated (4 complete blocks), extending the
                    // prompt must not move the placement.
                    if prompt.len() / block_tokens >= 4 {
                        let mut extended = prompt.clone();
                        extended.extend([123, 456, 789]);
                        if route_affinity(&extended, block_tokens, workers) != got {
                            return Err("suffix beyond hashed blocks moved routing".to_string());
                        }
                    }
                }
                None => {
                    if prompt.len() / block_tokens >= 1 {
                        return Err("declined although a complete block exists".to_string());
                    }
                }
            }
            Ok(())
        },
    );
}

// Drained-router accounting: a clean (fault-free) sharded run answers
// every request exactly once, orphans nothing, leaks no pool blocks,
// and every worker reports a clean drained engine.
#[test]
fn drained_router_accounts_for_every_request_and_block() {
    let seed = 0x40F7_ACC7u64;
    let spec = fuzz::FuzzSpec::from_seed(seed);
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = fuzz::build_workload(cfg.vocab, cfg.seq, &spec);
    let gen = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        ..GenConfig::default()
    };
    let rcfg = RouterConfig {
        workers: 3,
        worker_queue: 64,
        // No faults injected; disable stall supervision so the clean
        // run cannot see a spurious quarantine on a slow machine.
        stall_rounds: 0,
        trace: true,
        ..RouterConfig::default()
    };
    let (outs, report) =
        router_faults::run_sharded_workload(&rt, &params, &qm, gen, rcfg, &workload).unwrap();
    router_faults::check_router_accounting(seed, 0, workload.len(), &outs, &report).unwrap();
    assert_eq!(report.workers, 3);
    assert_eq!(report.crashes, 0, "clean run crashed: {}", report.summary_line());
    assert_eq!(report.stalls, 0);
    assert_eq!(report.rerouted, 0);
    assert_eq!(
        report.dispatches,
        workload.len(),
        "every request dispatched exactly once in a clean run"
    );
    assert!(
        report.per_worker.iter().all(|w| w.drained_clean),
        "every worker must drain with a clean pool check: {report:?}"
    );
    if workload
        .iter()
        .any(|(_, r)| r.prompt.len() >= spec.block_tokens)
    {
        assert!(
            report.affinity_routed > 0,
            "complete-block prompts present but nothing affinity-routed"
        );
    }
}

// --------------------------------- observability: trace determinism

// THE ISSUE-8 contract: tracing is an observer, not a participant.
// Enabling it must leave every token stream bitwise identical, and under
// the virtual clock the canonically rendered event sequence must be
// identical at 1/2/8 compute threads (`testutil::fuzz::
// trace_determinism_case`; the faulted runs in `fault_injection_case`
// pin the same property on the failure path).

#[test]
fn trace_determinism_pinned_seed_a() {
    fuzz::trace_determinism_case(0x7ACE_0001).unwrap();
}

#[test]
fn trace_determinism_pinned_seed_b() {
    fuzz::trace_determinism_case(0x7ACE_0002).unwrap();
}

#[test]
fn trace_determinism_pinned_seed_c() {
    fuzz::trace_determinism_case(0x7ACE_0003).unwrap();
}

/// CI's fresh-seed entry: `FAQUANT_TRACE_SEED=<u64>` (the trace-smoke
/// job derives it from the run id and echoes it, so any failure
/// reproduces locally with the same variable). A no-op when unset.
#[test]
fn trace_determinism_env_seed() {
    let Ok(raw) = std::env::var("FAQUANT_TRACE_SEED") else {
        println!("FAQUANT_TRACE_SEED unset; skipping the fresh-seed trace run");
        return;
    };
    let seed: u64 = raw
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("FAQUANT_TRACE_SEED must be a u64, got '{raw}'"));
    println!("running fresh-seed trace determinism: FAQUANT_TRACE_SEED={seed}");
    fuzz::trace_determinism_case(seed).unwrap();
}

// ------------------------------------- thread pool: poison recovery

#[test]
fn pool_poison_recovery_keeps_results_bitwise_identical() {
    // A panicking pool task (PR 6: workers recover the poisoned batch
    // mutex via `into_inner`) must not perturb anything computed after
    // it: the same matmul and the same decoded tokens, bit for bit, at
    // every thread count.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 808);
    let decode = || -> Vec<i32> {
        let mut eng = Engine::new(
            &rt,
            &cfg,
            &params,
            &qm,
            GenConfig {
                temperature: 0.9,
                top_k: 8,
                seed: 606,
                slots: 2,
                ..GenConfig::default()
            },
        )
        .unwrap();
        let (outs, _) = eng
            .generate(vec![GenRequest {
                id: 0,
                prompt: vec![1, 2, 3],
                max_new: 5,
                stop_id: None,
                ..Default::default()
            }])
            .unwrap();
        outs.into_iter().next().unwrap().tokens
    };
    let mut rng = Rng::new(99);
    let a = Tensor::randn(&mut rng, &[48, 64], 1.0);
    let b = Tensor::randn(&mut rng, &[64, 32], 1.0);
    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let mm_before = a.matmul(&b).unwrap();
        let tok_before = decode();
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par::par_map(8, |i| {
                if i == 3 {
                    panic!("injected pool-task panic");
                }
                i
            })
        }));
        assert!(poisoned.is_err(), "the injected panic must reach the caller");
        let mm_after = a.matmul(&b).unwrap();
        let tok_after = decode();
        par::set_threads(0);
        assert_eq!(
            mm_before.data(),
            mm_after.data(),
            "matmul diverged after a pool-task panic at {threads} threads"
        );
        assert_eq!(
            tok_before, tok_after,
            "decode diverged after a pool-task panic at {threads} threads"
        );
    }
}

// ------------------------------------ paged KV cache: pool invariants

#[test]
fn prop_block_pool_invariants_hold_under_random_workloads() {
    // `run_workload(check_invariants: true)` verifies after EVERY
    // scheduler step: free + in_use == pool_size, refcounts == table +
    // radix-tree references (so they can never have underflowed — release
    // fails loudly), reservations are backed by free blocks, and no
    // block is reachable from two diverged sequences after copy-on-write.
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 2024);
    forall(44, 5, &UsizeIn(1, 1_000_000), |&case| {
        let spec = fuzz::FuzzSpec::from_seed(case as u64 * 7919 + 3);
        let workload = fuzz::build_workload(cfg.vocab, cfg.seq, &spec);
        let gen = GenConfig {
            temperature: spec.temperature,
            top_k: spec.top_k,
            seed: spec.seed,
            slots: spec.slots,
            block_tokens: spec.block_tokens,
            pool_blocks: spec.pool_blocks,
            ..GenConfig::default()
        };
        let outs = fuzz::run_workload(&rt, &params, &qm, gen, &workload, true)
            .map_err(|e| e.to_string())?;
        if outs.len() != workload.len() {
            return Err(format!(
                "{} outputs for {} requests",
                outs.len(),
                workload.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn drained_paged_engine_returns_every_non_cached_block() {
    // After a full drain with the prefix cache DISABLED, every block
    // must be back on the free list (refcounts balanced to zero).
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, 555);
    let mut eng = Engine::new(
        &rt,
        &cfg,
        &params,
        &qm,
        GenConfig {
            slots: 3,
            block_tokens: 4,
            prefix_cache: false,
            ..GenConfig::default()
        },
    )
    .unwrap();
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| GenRequest {
            id: i,
            prompt: (0..5 + i).map(|k| ((k * 11 + i) % cfg.vocab) as i32).collect(),
            max_new: 4,
            stop_id: None,
            ..Default::default()
        })
        .collect();
    let (outs, rep) = eng.generate(reqs).unwrap();
    assert_eq!(outs.len(), 6);
    eng.check_paged_invariants().unwrap();
    let (free, in_use, pool, reserved) = eng.pool_stats().unwrap();
    assert_eq!(in_use, 0, "prefix cache off: drain must free everything");
    assert_eq!(free, pool);
    assert_eq!(reserved, 0);
    assert_eq!(rep.prefix_hit_tokens, 0);
    assert_eq!(eng.prefix_cache_nodes().unwrap(), 0);
}

// ------------------------------------------ radix tree vs naive oracle

/// Naive O(n^2) longest-prefix-match oracle over the raw inserted token
/// sequences, written independently of the tree.
fn oracle_match(entries: &[Vec<i32>], query: &[i32]) -> usize {
    let mut best = 0usize;
    for e in entries {
        let mut m = 0usize;
        while m < e.len() && m < query.len() && e[m] == query[m] {
            m += 1;
        }
        best = best.max(m);
    }
    best
}

#[test]
fn prop_radix_tree_matches_naive_oracle() {
    forall(45, 40, &UsizeIn(1, 1_000_000), |&case| {
        let mut rng = Rng::new(case as u64 * 131 + 7);
        let bt = 2 + rng.below(4); // 2..=5
        let vocab = 2 + rng.below(5); // tiny alphabet => dense overlaps
        let mut tree = RadixTree::new(bt);
        let mut entries: Vec<Vec<i32>> = Vec::new();
        let mut next_block = 0u32;
        for round in 0..8 {
            // Aligned inserts (the engine inserts floor(fed / bt) * bt).
            let blocks = 1 + rng.below(4);
            let tokens: Vec<i32> = (0..blocks * bt)
                .map(|_| rng.below(vocab) as i32)
                .collect();
            let base = next_block;
            next_block += blocks as u32;
            tree.insert(&tokens, |pos| base + (pos / bt) as u32, round as u64);
            tree.check_structure().map_err(|e| e.to_string())?;
            entries.push(tokens);

            // Random queries, arbitrary (unaligned) lengths — including
            // the partial-block boundary case prefix % bt != 0.
            for q in 0..4 {
                let qlen = 1 + rng.below(3 * bt + 2);
                let query: Vec<i32> = if q == 0 && !entries.is_empty() {
                    // Bias one query toward a cached entry + divergence.
                    let e = &entries[rng.below(entries.len())];
                    let keep = 1 + rng.below(e.len());
                    let mut v: Vec<i32> = e[..keep].to_vec();
                    v.push(vocab as i32); // diverges: outside alphabet
                    v
                } else {
                    (0..qlen).map(|_| rng.below(vocab) as i32).collect()
                };
                let want = oracle_match(&entries, &query);
                let (got, chain) = tree.lookup(&query, 100 + round as u64);
                if got != want {
                    return Err(format!(
                        "match {got} != oracle {want} (bt={bt}, query {query:?}, \
                         entries {entries:?})"
                    ));
                }
                if chain.len() != got.div_ceil(bt) {
                    return Err(format!(
                        "chain {} blocks != ceil({got} / {bt})",
                        chain.len()
                    ));
                }
                if chain.iter().any(|&b| b >= next_block) {
                    return Err("chain names a block no insert provided".into());
                }
            }
        }
        Ok(())
    });
}

// -------------------------------------------------------------- Theorem 1

/// Construct the theorem's scenario (paper Sec. 1 issue (i), "quantization
/// bias"): the *biased calibration sample* understates channel `m_fut`,
/// which is genuinely important — its true activation magnitude (revealed
/// both by the future layers' statistics and by the deployment
/// distribution) is large, and the weight rows it feeds are heavy
/// (theorem assumption i). AWQ scales from the biased current-layer stats
/// alone and under-protects row m_fut; FAQ fuses the future-layer
/// statistics, recovers the protection, and achieves lower error on the
/// TRUE activation distribution (delta_FAQ < delta_AWQ, eq. 9).
fn theorem1_case(seed: u64) -> (f32, f32) {
    let mut rng = Rng::new(seed);
    let (n, m, group, bits) = (64usize, 64usize, 32usize, 3u32);
    let m_cur = rng.below(n);
    let mut m_fut = rng.below(n);
    if m_fut == m_cur {
        m_fut = (m_fut + 1) % n;
    }

    // Biased calibration sample: channel m_cur dominates, m_fut looks
    // ordinary (the sample missed the contexts where m_fut fires).
    let rows = 128;
    let mut a_cal = Tensor::randn(&mut rng, &[rows, n], 0.5);
    for r in 0..rows {
        a_cal.data_mut()[r * n + m_cur] *= 20.0;
    }
    // True deployment activations: m_fut is in fact a large channel too.
    let mut a_true = Tensor::randn(&mut rng, &[rows, n], 0.5);
    for r in 0..rows {
        a_true.data_mut()[r * n + m_cur] *= 20.0;
        a_true.data_mut()[r * n + m_fut] *= 20.0;
    }
    // Weights: row m_fut heavy (assumption i — the (j,k) positions are
    // large through layers i..I).
    let mut w = Tensor::randn(&mut rng, &[n, m], 0.4);
    for c in 0..m {
        w.data_mut()[m_fut * m + c] *= 4.0;
    }

    // Stats: AWQ sees only the biased calibration; the future layers'
    // activations reveal m_fut (it keeps growing downstream).
    let cur_stats = a_cal.absmean_cols();
    let mut fut_stats = cur_stats.clone();
    fut_stats[m_fut] = 8.0;

    let y_fp = a_true.matmul(&w).unwrap();
    let best_err = |stats: &[f32]| -> f32 {
        let mut best = f32::INFINITY;
        for alpha in alpha_grid(10) {
            let s = alpha_scale(stats, alpha);
            let wq = scaled_fakequant(&w, &s, bits, group).unwrap();
            // Alpha is chosen on calibration (as the method would), but
            // delta is measured on the true distribution.
            let err = a_true.matmul(&wq).unwrap().dist2(&y_fp);
            best = best.min(err);
        }
        best
    };
    let awq = best_err(&cur_stats);
    let faq = best_err(&fused_stats(&cur_stats, &fut_stats, 0.85));
    (faq, awq)
}

#[test]
fn theorem1_faq_error_below_awq() {
    // Paper eq. 9: delta_FAQ < delta_AWQ under the outlier assumptions.
    // Verified across many random instantiations of the construction;
    // allow rare statistical ties but require strict inequality in the
    // aggregate and in >= 70% of cases.
    let mut wins = 0;
    let mut total_faq = 0.0;
    let mut total_awq = 0.0;
    let cases = 20;
    for seed in 0..cases {
        let (faq, awq) = theorem1_case(seed as u64 * 1009 + 7);
        total_faq += faq;
        total_awq += awq;
        if faq < awq {
            wins += 1;
        }
    }
    assert!(
        wins as f32 >= 0.7 * cases as f32,
        "FAQ won only {wins}/{cases} cases"
    );
    assert!(
        total_faq < total_awq,
        "aggregate: FAQ {total_faq} !< AWQ {total_awq}"
    );
}

#[test]
fn theorem1_collapses_when_no_future_signal() {
    // Control: if the future stats equal the current stats, FAQ == AWQ
    // (the inequality is driven by the future information, not by the
    // fusion arithmetic).
    let mut rng = Rng::new(5);
    let w = Tensor::randn(&mut rng, &[64, 32], 1.0);
    let a = Tensor::randn(&mut rng, &[64, 64], 1.0);
    let stats = a.absmean_cols();
    let fused = fused_stats(&stats, &stats, 0.85);
    for (x, y) in fused.iter().zip(&stats) {
        assert!((x - y).abs() < 1e-6);
    }
    let s1 = alpha_scale(&stats, 0.5);
    let s2 = alpha_scale(&fused, 0.5);
    let q1 = scaled_fakequant(&w, &s1, 3, 32).unwrap();
    let q2 = scaled_fakequant(&w, &s2, 3, 32).unwrap();
    assert!(q1.mse(&q2) < 1e-10);
}

// ------------------------------------------------------- sanitizer canary

#[test]
fn tsan_canary_detects_data_race() {
    // Wired to the nightly `tsan-determinism` job's must-fail step: with
    // FAQUANT_TSAN_CANARY set, two threads race on an `UnsafeCell<u64>`
    // with no synchronization and ThreadSanitizer MUST report the race.
    // If this ever passes under TSan, the job's race detection is broken
    // (wrong RUSTFLAGS, missing -Zbuild-std), not the code. The env gate
    // keeps the race out of every normal `cargo test` run.
    if std::env::var_os("FAQUANT_TSAN_CANARY").is_none() {
        return;
    }
    struct Racy(std::cell::UnsafeCell<u64>);
    // SAFETY: deliberately unsound — the whole point of this canary is
    // to hand two threads unsynchronized mutable access so TSan fires.
    unsafe impl Sync for Racy {}
    let racy = Racy(std::cell::UnsafeCell::new(0));
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..1000 {
                    unsafe { *racy.0.get() += 1 };
                }
            });
        }
    });
    let v = unsafe { *racy.0.get() };
    assert!(v > 0);
}
