//! Quickstart: quantize a tiny trained LM with FAQ in ~10 seconds.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the full public API surface once: runtime -> pipeline ->
//! checkpoint -> calibration -> FAQ quantization -> perplexity eval.

use anyhow::Result;
use faquant::config::{Method, RunConfig};
use faquant::coordinator::Pipeline;
use faquant::runtime::Runtime;
use std::path::Path;

fn main() -> Result<()> {
    // 1. Load the AOT artifact registry + PJRT CPU client.
    let rt = Runtime::new(Path::new("artifacts"))?;
    println!("platform: {}", rt.platform());

    // 2. Configure a run: pico model, FAQ at 3 bits, small budgets.
    let mut cfg = RunConfig::new("pico")?;
    cfg.train_steps = 100;
    cfg.eval_seqs = 8;
    cfg.task_items = 16;
    cfg.quant.method = Method::Faq;
    cfg.quant.bits = 3;

    // 3. Run the pipeline: checkpoint -> calibrate -> quantize -> eval.
    let pipe = Pipeline::new(&rt, cfg);
    let out = pipe.run()?;

    let qm = out.quantized.expect("FAQ quantizes");
    let (packed, fp) = qm.compression();
    println!("\n== quickstart result ==");
    println!("mean reconstruction loss: {:.4e}", qm.mean_loss());
    println!(
        "packed weights: {} KiB (fp32 {} KiB, {:.2}x smaller)",
        packed / 1024,
        fp / 1024,
        fp as f32 / packed as f32
    );
    for l in qm.linears.iter().take(4) {
        println!(
            "  blk{}.{:<5} alpha={:.2} window={} gamma={:.2} loss={:.3e}",
            l.block, l.role, l.alpha, l.window_used, l.gamma_used, l.loss
        );
    }
    let row = out.eval.expect("pipeline evaluates");
    println!(
        "perplexity: synth-wikitext2 {:.3}, synth-c4 {:.3}",
        row.ppl_wiki, row.ppl_c4
    );
    Ok(())
}
