//! END-TO-END VALIDATION DRIVER (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real small workload:
//!   1. trains a transformer LM for a few hundred steps via the AOT
//!      `train_step` artifact (L2 fwd/bwd + AdamW), logging the loss curve;
//!   2. calibrates on the captured activations (L1 absmean kernel on-graph);
//!   3. quantizes with RTN / AWQ / FAQ (L3 grid search over the Pallas
//!      `scaled_fakequant` loss artifact);
//!   4. evaluates perplexity on both synthetic corpora + all six zero-shot
//!      suites per method (the paper's Table-1 row for this model);
//!   5. serves batched requests through the INT-code `fwd_logits_q`
//!      deployment artifact (L1 qmatmul kernel), reporting latency.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```
//! Results are printed as markdown and recorded in EXPERIMENTS.md.

use anyhow::Result;
use faquant::benchkit::{f4, Table};
use faquant::config::{Method, RunConfig};
use faquant::coordinator::Pipeline;
use faquant::eval::{canonical_tokenizer, eval_all};
use faquant::runtime::Runtime;
use faquant::train::{ensure_checkpoint, fit_tokenizer, train};
use std::path::Path;
use std::time::Duration;

const MODEL: &str = "nano";
const STEPS: usize = 400;

fn main() -> Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let mut cfg = RunConfig::new(MODEL)?;
    cfg.train_steps = STEPS;
    cfg.eval_seqs = 16;
    cfg.task_items = 32;

    // ---- 1. train (or show the cached curve by retraining a stub) ------
    println!("## end-to-end: {MODEL} ({} params)\n", cfg.model.param_count());
    let outcome = ensure_checkpoint(&rt, &cfg.model, &cfg.runs_dir, STEPS, 17)?;
    if outcome.cached {
        println!("checkpoint cached; sampling a fresh 40-step curve for the log:");
        let init = faquant::model::Params::init(&cfg.model, 17);
        let (_tok, ids) = fit_tokenizer(&cfg.model, 40);
        let (_p, curve) = train(&rt, &cfg.model, &init, &ids, 40, 10)?;
        for (s, l) in curve {
            println!("  step {s:>4}  loss {l:.4}");
        }
    } else {
        println!("loss curve ({} steps):", STEPS);
        for (s, l) in &outcome.curve {
            println!("  step {s:>4}  loss {l:.4}");
        }
    }
    let params = outcome.params;

    // ---- 2. calibrate ---------------------------------------------------
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (calib, secs) = pipe.calibrate(&params)?;
    println!("\ncalibration: N={} seqs in {secs:.1}s", cfg.calib_seqs);

    // ---- 3+4. quantize with each method and evaluate --------------------
    let tok = canonical_tokenizer(&cfg.model);
    let mut table = Table::new(
        &format!("{MODEL} @ 3-bit (group {})", cfg.quant.group),
        &[
            "Quant", "wikitext2", "c4", "arc_challenge", "hellaswag", "winogrande",
            "arc_easy", "boolq", "piqa",
        ],
    );
    let mut faq_model = None;
    for method in [Method::Fp, Method::Rtn, Method::Awq, Method::Faq] {
        let eval_params = if method == Method::Fp {
            params.clone()
        } else {
            let mut c = cfg.clone();
            c.quant.method = method;
            let p = Pipeline::new(&rt, c);
            let (qm, _) = p.quantize(&params, Some(&calib))?;
            let fq = qm.fq_params.clone();
            if method == Method::Faq {
                faq_model = Some(qm);
            }
            fq
        };
        let row = eval_all(&rt, &cfg.model, &eval_params, &tok, cfg.eval_seqs, cfg.task_items)?;
        let mut cells = vec![method.name().to_string(), f4(row.ppl_wiki), f4(row.ppl_c4)];
        for (_, acc) in &row.accs {
            cells.push(f4(*acc));
        }
        table.row(cells);
    }
    println!("{}", table.markdown());

    // ---- 5. serve through the quantized deployment artifact -------------
    let qm = faq_model.expect("FAQ ran");
    let (packed, fp) = qm.compression();
    println!(
        "deployment bundle: {} KiB packed vs {} KiB fp32 ({:.2}x)",
        packed / 1024,
        fp / 1024,
        fp as f32 / packed as f32
    );
    let ids = faquant::eval::calib_ids(&cfg.model, &tok, 40, 4242);
    let seqs = faquant::corpus::Batcher::new(1, cfg.model.seq).eval_batches(&ids)?;
    let (tx, rx) = std::sync::mpsc::channel();
    let mut resp = Vec::new();
    for i in 0..32 {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(faquant::serve::Request {
            tokens: seqs[i % seqs.len()].data().to_vec(),
            respond: rtx,
        })?;
        resp.push(rrx);
    }
    drop(tx);
    let rep = faquant::serve::serve_requests(
        &rt,
        &cfg.model,
        &params,
        &qm,
        rx,
        Duration::from_millis(5),
        None,
    )?;
    let ok = resp
        .into_iter()
        .filter(|r| matches!(r.recv(), Ok(faquant::serve::Response::Done(_))))
        .count();
    println!(
        "served {ok}/{} requests, {} batches (fill {:.0}%), p50 {:.1} ms p95 {:.1} ms, {:.1} req/s",
        rep.requests,
        rep.batches,
        rep.mean_batch_fill * 100.0,
        rep.p50_ms,
        rep.p95_ms,
        rep.throughput_rps
    );
    println!("\nend_to_end OK — all three layers composed.");
    Ok(())
}
