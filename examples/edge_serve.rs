//! Edge-serving demo: batched next-token inference over the INT-code
//! deployment artifact (`fwd_logits_q`, Pallas qmatmul kernel), with a
//! client thread firing requests through an mpsc queue and the batcher
//! padding partial batches — the paper's motivating deployment scenario.
//!
//! ```bash
//! cargo run --release --offline --example edge_serve -- 96
//! ```

use anyhow::Result;
use faquant::config::RunConfig;
use faquant::coordinator::Pipeline;
use faquant::eval::{calib_ids, canonical_tokenizer};
use faquant::runtime::Runtime;
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

fn main() -> Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);

    let rt = Runtime::new(Path::new("artifacts"))?;
    let mut cfg = RunConfig::new("pico")?;
    cfg.train_steps = 100;
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let (calib, _) = pipe.calibrate(&params)?;
    let (qm, _) = pipe.quantize(&params, Some(&calib))?;
    let (packed, fp) = qm.compression();
    println!(
        "quantized model: {} KiB packed ({:.2}x smaller than fp32)",
        packed / 1024,
        fp as f32 / packed as f32
    );

    // Client side: one producer thread enqueues token sequences.
    let tok = canonical_tokenizer(&cfg.model);
    let ids = calib_ids(&cfg.model, &tok, n_requests + 8, 31337);
    let seqs = faquant::corpus::Batcher::new(1, cfg.model.seq).eval_batches(&ids)?;
    let (tx, rx) = mpsc::channel();
    let mut responders = Vec::new();
    for i in 0..n_requests {
        let (rtx, rrx) = faquant::serve::oneshot_channel();
        tx.send(faquant::serve::Request {
            tokens: seqs[i % seqs.len()].data().to_vec(),
            respond: rtx,
        })?;
        responders.push(rrx);
    }
    drop(tx); // close the queue: server drains and exits

    let report = faquant::serve::serve_requests(
        &rt,
        &cfg.model,
        &params,
        &qm,
        rx,
        Duration::from_millis(2),
        None,
    )?;

    // Every client sees its own next-token distribution.
    let mut answered = 0;
    for r in responders {
        if let Ok(faquant::serve::Response::Done(c)) = r.recv() {
            assert_eq!(c.next_logits.len(), cfg.model.vocab);
            assert!(c.done_at >= c.queued_at);
            answered += 1;
        }
    }
    println!(
        "answered {answered}/{} | {} batches, mean fill {:.0}% | \
         p50 {:.2} ms, p95 {:.2} ms | {:.1} req/s",
        report.requests,
        report.batches,
        report.mean_batch_fill * 100.0,
        report.p50_ms,
        report.p95_ms,
        report.throughput_rps
    );
    Ok(())
}
