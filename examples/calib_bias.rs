//! Calibration-bias robustness demo (the paper's Table 3 scenario).
//!
//! Draws calibration sets of N = 8, 16, 32 sequences with different seeds
//! (smaller N = more sampling bias), quantizes with AWQ and FAQ, and
//! reports the per-N perplexities plus mean/std. The paper's claim: FAQ's
//! window-wise preview averages statistics across layers, damping the
//! effect of a biased sample — lower std than AWQ.
//!
//! ```bash
//! cargo run --release --offline --example calib_bias
//! ```

use anyhow::Result;
use faquant::benchkit::{f4, Table};
use faquant::config::{Method, RunConfig};
use faquant::coordinator::Pipeline;
use faquant::eval::{canonical_tokenizer, perplexity};
use faquant::corpus::CorpusKind;
use faquant::runtime::Runtime;
use faquant::tensor::mean_std;
use std::path::Path;

fn main() -> Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let mut cfg = RunConfig::new("pico")?;
    cfg.train_steps = 200;
    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint()?;
    let tok = canonical_tokenizer(&cfg.model);

    let ns = [8usize, 16, 32];
    let mut table = Table::new(
        "Calibration-bias robustness (pico, 3-bit)",
        &["Method", "N", "wikitext2", "c4"],
    );
    for method in [Method::Awq, Method::Faq] {
        let mut wikis = Vec::new();
        for (i, &n) in ns.iter().enumerate() {
            let mut c = cfg.clone();
            c.quant.method = method;
            c.calib_seqs = n;
            c.calib_seed = 300 + i as u64;
            let p = Pipeline::new(&rt, c.clone());
            let (calib, _) = p.calibrate(&params)?;
            let (qm, _) = p.quantize(&params, Some(&calib))?;
            let wiki = perplexity(&rt, &c.model, &qm.fq_params, &tok, CorpusKind::SynthWiki, 8)?;
            let c4 = perplexity(&rt, &c.model, &qm.fq_params, &tok, CorpusKind::SynthC4, 8)?;
            wikis.push(wiki);
            table.row(vec![
                method.name().into(),
                n.to_string(),
                f4(wiki),
                f4(c4),
            ]);
        }
        let (m, s) = mean_std(&wikis);
        table.row(vec![method.name().into(), "mean±std".into(), f4(m), format!("±{}", f4(s))]);
    }
    println!("{}", table.markdown());
    println!("expected shape: FAQ's std <= AWQ's std (preview damps bias).");
    Ok(())
}
